//! The multi-request serve path: a pool of sessions sharing one knowledge
//! base drains a stream of requests under an admission cap — the first
//! building block of the ROADMAP's "heavy traffic" north star.
//!
//! [`SessionPool`] owns N [`Session`]s (one backend each — the paper's
//! one-machine contract) wired to a single shared KB, so the first cold
//! start warms every worker: whichever session builds a profile, the rest
//! resolve the same computation as KB hits. [`SessionPool::serve`] spawns
//! one scoped worker thread per session; workers pull requests off a shared
//! cursor until the stream drains, recording per-request latency for the
//! p50/p99 report.
//!
//! Analytic backends price an execution and return immediately, which
//! makes a throughput number meaningless; [`ServeOpts::pace`] inserts a
//! fixed per-request service floor (sleep) that stands in for device
//! occupancy, so requests/sec measures genuine admission-cap scaling. Real
//! backends leave `pace` at 0.
//!
//! **Co-scheduling** ([`ServeOpts::co_schedule`], DESIGN.md §2.8): instead
//! of every request implicitly owning the whole device pool, admission
//! prices each request's KB-estimated cost against every device subset
//! ([`candidate_masks`]) — derated by the subset's capacity share, plus the
//! migration cost of residency parked on excluded devices and the wait for
//! conflicting reservations already admitted — and reserves the subset
//! minimizing predicted completion. A CPU-friendly request then runs on
//! the CPU sub-devices while a GPU-heavy one owns the GPUs, and the
//! work-stealing launcher never crosses the reservation boundary.
//!
//! **Batching & graph fusion** ([`ServeOpts::batch_max`], DESIGN.md
//! §2.10): at concurrency ≫ slot count, draining every request as its own
//! graph pays admission, reservation, pacing, and launch overhead N times
//! over. A worker therefore claims a *batch* of consecutive compatible
//! requests (sync-free stage programs — [`fusable`]) and drains them as
//! one fused unit: one admission and reservation priced by the KB's
//! fused-batch estimate, one pace floor, and one virtual-timeline booking
//! at the fused makespan ([`ExecOutcome::fused_total`]) — opposite-leaning
//! members fill each other's idle device time instead of serializing.
//! Batches close on a size budget, a byte budget, or when the projected
//! fused drain would overrun the batch window or the oldest member's
//! deadline slack ([`ServeRequest::deadline`]). Per-request results stay
//! bit-identical to solo runs: every member executes its own graph with
//! its own arguments, and traces attribute each member's admission wait
//! and drain separately.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::data::workload::Workload;
use crate::decompose::graph::fusable;
use crate::error::Result;
use crate::kb::{pack_estimate, KnowledgeBase};
use crate::platform::device::Machine;
use crate::runtime::exec::RequestArgs;
use crate::scheduler::{
    candidate_masks, ExecEnv, ExecOutcome, SlotMask, SlotReservations,
    VirtualTimeline,
};
use crate::session::exec_profile::ExecProfile;
use crate::session::{Computation, ConfigOrigin, Session, SessionStats};
use crate::util::json::Json;
use crate::util::stats::percentile;

/// One queued request: a computation plus its arguments and SLO terms.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub comp: Computation,
    pub args: RequestArgs,
    /// Relative completion deadline in seconds from claim (the request's
    /// SLO budget). Batch assembly never stretches a batch past any
    /// member's remaining slack; a request whose end-to-end latency
    /// exceeds the deadline is reported as a miss. `None` falls back to
    /// [`ServeOpts::deadline_default`].
    pub deadline: Option<f64>,
    /// Scheduling priority: higher values shrink the batch window the
    /// request tolerates (a priority-p member accepts `window / (1 + p)`
    /// of fusion-induced stretch), so latency-critical requests ride in
    /// small batches or solo.
    pub priority: u32,
    /// Arrival offset in seconds from stream start (trace replay,
    /// DESIGN.md §2.13): a worker claiming this request waits until the
    /// offset has elapsed before starting admission, and batch assembly
    /// never fuses a request arriving more than [`ServeOpts::batch_window`]
    /// after its batch head — so a replayed stream reproduces the recorded
    /// run's batch boundaries. 0 (the default) is the PR 7 behavior: the
    /// whole stream is available up front.
    pub arrival_offset: f64,
}

impl From<Computation> for ServeRequest {
    fn from(comp: Computation) -> ServeRequest {
        ServeRequest {
            comp,
            args: RequestArgs::default(),
            deadline: None,
            priority: 0,
            arrival_offset: 0.0,
        }
    }
}

impl ServeRequest {
    pub fn with_deadline(mut self, secs: f64) -> ServeRequest {
        self.deadline = Some(secs);
        self
    }

    pub fn with_priority(mut self, priority: u32) -> ServeRequest {
        self.priority = priority;
        self
    }

    pub fn with_arrival_offset(mut self, secs: f64) -> ServeRequest {
        self.arrival_offset = secs.max(0.0);
        self
    }
}

/// Serving knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeOpts {
    /// Admission cap: how many requests may be in flight at once (bounded
    /// by the pool size).
    pub concurrency: usize,
    /// Per-request service floor in seconds (see module docs). 0 disables.
    pub pace: f64,
    /// Execution profile applied to every pooled session before the
    /// stream drains (DESIGN.md §2.13) — the one struct that replaced the
    /// per-knob `tasks_per_slot`/`drain_mode`/`prefetch_depth` options.
    /// Empty (the default) keeps every backend default; replay traces
    /// carry the profile their run served under.
    pub exec: ExecProfile,
    /// Device-space co-scheduling (`--co-schedule`, DESIGN.md §2.8): admit
    /// each request onto the KB-cost-priced device subset minimizing its
    /// predicted completion, instead of time-sharing the whole pool. Off
    /// by default (the PR 2 whole-pool behavior).
    pub co_schedule: bool,
    /// Flush the durable KB store (DESIGN.md §2.9) every N completed
    /// requests, picking up segments other processes committed in the
    /// meantime. 0 (the default) syncs once at the end of the run; the
    /// knob is a no-op when the shared KB has no store backing.
    pub store_sync_every: usize,
    /// Most requests one batch may coalesce (`--batch-max`, DESIGN.md
    /// §2.10). 1 (the default) disables batching: every request drains
    /// solo, the PR 5 behavior.
    pub batch_max: usize,
    /// Batch window in seconds (`--batch-window`): the most
    /// fusion-induced stretch the oldest member's projected completion
    /// may absorb before the batch closes. Scaled down by member priority
    /// (see [`ServeRequest::priority`]).
    pub batch_window: f64,
    /// Byte budget per batch: assembly stops before the members' summed
    /// working sets exceed this (keeps a fused drain inside the residency
    /// pool's working capacity).
    pub batch_bytes: u64,
    /// Deadline applied to requests that carry none
    /// (`--deadline-default`); `None` leaves them deadline-free.
    pub deadline_default: Option<f64>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            concurrency: 1,
            pace: 0.0,
            exec: ExecProfile::default(),
            co_schedule: false,
            store_sync_every: 0,
            batch_max: 1,
            batch_window: 2e-3,
            batch_bytes: 64 << 20,
            deadline_default: None,
        }
    }
}

impl ServeOpts {
    /// JSON form — replay traces embed the opts their run served under.
    /// Sparse where it can be: the exec profile and the deadline default
    /// are emitted only when set.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("concurrency", Json::num(self.concurrency as f64)),
            ("pace", Json::num(self.pace)),
            ("co_schedule", Json::Bool(self.co_schedule)),
            ("store_sync_every", Json::num(self.store_sync_every as f64)),
            ("batch_max", Json::num(self.batch_max as f64)),
            ("batch_window", Json::num(self.batch_window)),
            ("batch_bytes", Json::num(self.batch_bytes as f64)),
        ];
        if let Some(d) = self.deadline_default {
            fields.push(("deadline_default", Json::num(d)));
        }
        if !self.exec.is_empty() {
            fields.push(("exec", self.exec.to_json()));
        }
        Json::obj(fields)
    }

    /// Inverse of [`ServeOpts::to_json`]; absent keys keep the defaults.
    pub fn from_json(v: &Json) -> Result<ServeOpts> {
        let d = ServeOpts::default();
        let usize_or = |k: &str, d: usize| {
            v.get(k).ok().and_then(|x| x.as_u64()).map(|n| n as usize).unwrap_or(d)
        };
        let f64_or =
            |k: &str, d: f64| v.get(k).ok().and_then(|x| x.as_f64()).unwrap_or(d);
        Ok(ServeOpts {
            concurrency: usize_or("concurrency", d.concurrency),
            pace: f64_or("pace", d.pace),
            exec: match v.get("exec") {
                Ok(e) => ExecProfile::from_json(e)?,
                Err(_) => ExecProfile::default(),
            },
            co_schedule: v
                .get("co_schedule")
                .ok()
                .and_then(|x| x.as_bool())
                .unwrap_or(d.co_schedule),
            store_sync_every: usize_or("store_sync_every", d.store_sync_every),
            batch_max: usize_or("batch_max", d.batch_max),
            batch_window: f64_or("batch_window", d.batch_window),
            batch_bytes: v
                .get("batch_bytes")
                .ok()
                .and_then(|x| x.as_u64())
                .unwrap_or(d.batch_bytes),
            deadline_default: v.get("deadline_default").ok().and_then(|x| x.as_f64()),
        })
    }
}

/// One served request's record.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// Index into the request stream.
    pub index: usize,
    /// Which pool worker served it.
    pub worker: usize,
    /// Wall seconds from claim to batch completion (including the pace
    /// floor): what the client observes end to end.
    pub latency: f64,
    /// Wall seconds from claim to this request's own drain start:
    /// admission pricing, reservation wait, and — in a batch — the
    /// batch-mates drained ahead of it. The batching cost side of the
    /// ledger; `latency - admit_wait` is never attributable to admission.
    pub admit_wait: f64,
    /// Wall seconds this request's own drain took (its `Session::run`).
    pub drain: f64,
    pub origin: ConfigOrigin,
    /// The execution's own completion time.
    pub exec_total: f64,
    /// The device subset the request was admitted onto (`None` without
    /// co-scheduling: the request implicitly owned the whole pool).
    pub mask: Option<SlotMask>,
    /// Which batch this request rode in (batch ids are per serve run) and
    /// how many members that batch coalesced (1 = solo drain).
    pub batch: usize,
    pub batch_size: usize,
    /// Whether end-to-end latency overran the request's effective
    /// deadline (own, or [`ServeOpts::deadline_default`]).
    pub deadline_missed: bool,
    /// Whether the effective deadline came from
    /// [`ServeOpts::deadline_default`] rather than the request itself.
    /// Recorded so a replay can re-apply the default at admission instead
    /// of baking the resolved value into the request — explicit and
    /// defaulted deadlines batch identically but must round-trip
    /// distinguishably (DESIGN.md §2.13).
    pub deadline_defaulted: bool,
}

impl RequestTrace {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("index", Json::num(self.index as f64)),
            ("worker", Json::num(self.worker as f64)),
            ("latency", Json::num(self.latency)),
            ("admit_wait", Json::num(self.admit_wait)),
            ("drain", Json::num(self.drain)),
            ("origin", Json::str(self.origin.label())),
            ("exec_total", Json::num(self.exec_total)),
            ("batch", Json::num(self.batch as f64)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("deadline_missed", Json::Bool(self.deadline_missed)),
            ("deadline_defaulted", Json::Bool(self.deadline_defaulted)),
        ];
        if let Some(m) = &self.mask {
            fields.push((
                "mask",
                Json::obj(vec![
                    ("cpu", Json::Bool(m.cpu)),
                    (
                        "gpus",
                        Json::arr(m.gpus.iter().map(|&g| Json::Bool(g)).collect()),
                    ),
                ]),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<RequestTrace> {
        let usize_of = |k: &str| -> Result<usize> {
            Ok(v.get(k)?.as_u64().ok_or_else(|| {
                crate::error::Error::Kb(format!("trace field '{k}' must be an integer"))
            })? as usize)
        };
        let f64_of = |k: &str| -> Result<f64> {
            v.get(k)?.as_f64().ok_or_else(|| {
                crate::error::Error::Kb(format!("trace field '{k}' must be a number"))
            })
        };
        let origin_label = v.get("origin")?.as_str().unwrap_or("").to_string();
        let origin = ConfigOrigin::parse(&origin_label).ok_or_else(|| {
            crate::error::Error::Kb(format!("unknown config origin '{origin_label}'"))
        })?;
        let mask = match v.get("mask") {
            Ok(m) => Some(SlotMask {
                cpu: m.get("cpu")?.as_bool().unwrap_or(false),
                gpus: m
                    .get("gpus")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|g| g.as_bool().unwrap_or(false))
                    .collect(),
            }),
            Err(_) => None,
        };
        Ok(RequestTrace {
            index: usize_of("index")?,
            worker: usize_of("worker")?,
            latency: f64_of("latency")?,
            admit_wait: f64_of("admit_wait")?,
            drain: f64_of("drain")?,
            origin,
            exec_total: f64_of("exec_total")?,
            mask,
            batch: usize_of("batch")?,
            batch_size: usize_of("batch_size")?,
            deadline_missed: v
                .get("deadline_missed")
                .ok()
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            deadline_defaulted: v
                .get("deadline_defaulted")
                .ok()
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

/// Aggregate outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    pub concurrency: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mean_latency: f64,
    /// Latency split (DESIGN.md §2.10): admission/batch-wait vs drain
    /// percentiles, so batching's amortization gain and the wait it
    /// introduces are separately visible and gateable.
    pub p50_admit_wait: f64,
    pub p99_admit_wait: f64,
    pub p50_drain: f64,
    pub p99_drain: f64,
    /// How many batches the stream drained as (== completed when
    /// batching is off) and how many requests overran their deadline.
    pub batches: usize,
    pub deadline_misses: usize,
    /// Whether this run admitted requests onto device subsets.
    pub co_scheduled: bool,
    /// Completion time of the whole stream on the [`VirtualTimeline`]
    /// model: requests booked on conflicting device subsets stack up,
    /// disjoint ones overlap. Without co-scheduling every request books
    /// the full pool, so this is the serialized sum — the A/B baseline
    /// the co-scheduling win is measured against, noise-free even on
    /// analytic backends.
    pub virtual_makespan: f64,
    /// Session counters for this serve run (pool-summed delta, so reusing
    /// a pool across serve calls still reports per-run numbers).
    pub stats: SessionStats,
    pub traces: Vec<RequestTrace>,
}

impl ServeReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests in {:.3}s @ concurrency {} -> {:.1} req/s \
             (p50 {:.2}ms, p99 {:.2}ms; admit p50/p99 {:.2}/{:.2}ms, \
             drain p50/p99 {:.2}/{:.2}ms; {} batches, {} deadline misses; \
             {} kb hits ({} warm-started), \
             {} built ({:.2}s cold-build), {} derived; \
             {:.1} MB uploaded ({:.1}% overlapped), {} uploads avoided, \
             {} steal migrations; \
             mean slot idle {:.1}%; {} device-time {:.3}s)",
            self.completed,
            self.wall_secs,
            self.concurrency,
            self.requests_per_sec,
            self.p50_latency * 1e3,
            self.p99_latency * 1e3,
            self.p50_admit_wait * 1e3,
            self.p99_admit_wait * 1e3,
            self.p50_drain * 1e3,
            self.p99_drain * 1e3,
            self.batches,
            self.deadline_misses,
            self.stats.kb_hits,
            self.stats.warm_hits,
            self.stats.built,
            self.stats.build_secs,
            self.stats.derived,
            self.stats.bytes_uploaded as f64 / 1e6,
            self.stats.overlap_pct(),
            self.stats.uploads_avoided,
            self.stats.steal_migrations,
            self.stats.mean_idle_pct(),
            if self.co_scheduled {
                "co-scheduled"
            } else {
                "whole-pool"
            },
            self.virtual_makespan
        )
    }

    /// Requests per second of *device time*: the stream's size over the
    /// virtual makespan. Deterministic on analytic backends (no wall-clock
    /// noise), which is what the CI bench gate compares.
    pub fn virtual_req_per_sec(&self) -> f64 {
        if self.virtual_makespan <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.virtual_makespan
        }
    }

    /// Versioned JSON form ([`TRACE_VERSION`]): what `marrow serve
    /// --record` embeds as the recorded run's outcome.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace_version", Json::num(TRACE_VERSION as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("concurrency", Json::num(self.concurrency as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("requests_per_sec", Json::num(self.requests_per_sec)),
            ("p50_latency", Json::num(self.p50_latency)),
            ("p99_latency", Json::num(self.p99_latency)),
            ("mean_latency", Json::num(self.mean_latency)),
            ("p50_admit_wait", Json::num(self.p50_admit_wait)),
            ("p99_admit_wait", Json::num(self.p99_admit_wait)),
            ("p50_drain", Json::num(self.p50_drain)),
            ("p99_drain", Json::num(self.p99_drain)),
            ("batches", Json::num(self.batches as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("co_scheduled", Json::Bool(self.co_scheduled)),
            ("virtual_makespan", Json::num(self.virtual_makespan)),
            ("stats", self.stats.to_json()),
            (
                "traces",
                Json::arr(self.traces.iter().map(RequestTrace::to_json).collect()),
            ),
        ])
    }

    /// Inverse of [`ServeReport::to_json`]. Rejects newer trace versions.
    pub fn from_json(v: &Json) -> Result<ServeReport> {
        check_trace_version(v)?;
        let usize_or =
            |k: &str| v.get(k).ok().and_then(|x| x.as_u64()).unwrap_or(0) as usize;
        let f64_or = |k: &str| v.get(k).ok().and_then(|x| x.as_f64()).unwrap_or(0.0);
        let traces = v
            .get("traces")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(RequestTrace::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ServeReport {
            completed: usize_or("completed"),
            concurrency: usize_or("concurrency"),
            wall_secs: f64_or("wall_secs"),
            requests_per_sec: f64_or("requests_per_sec"),
            p50_latency: f64_or("p50_latency"),
            p99_latency: f64_or("p99_latency"),
            mean_latency: f64_or("mean_latency"),
            p50_admit_wait: f64_or("p50_admit_wait"),
            p99_admit_wait: f64_or("p99_admit_wait"),
            p50_drain: f64_or("p50_drain"),
            p99_drain: f64_or("p99_drain"),
            batches: usize_or("batches"),
            deadline_misses: usize_or("deadline_misses"),
            co_scheduled: v
                .get("co_scheduled")
                .ok()
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            virtual_makespan: f64_or("virtual_makespan"),
            stats: match v.get("stats") {
                Ok(s) => SessionStats::from_json(s),
                Err(_) => SessionStats::default(),
            },
            traces,
        })
    }
}

/// Version tag of the replayable-trace schema: `marrow serve --record`
/// output, `--replay` input, and serialized [`ServeReport`]s all carry it.
/// Bumped on incompatible changes; readers reject newer versions with a
/// clean error instead of misparsing.
pub const TRACE_VERSION: u64 = 1;

/// Reject documents written by a newer schema than this build understands.
fn check_trace_version(v: &Json) -> Result<()> {
    let version = v.get("trace_version")?.as_u64().unwrap_or(0);
    if version == 0 || version > TRACE_VERSION {
        return Err(crate::error::Error::Kb(format!(
            "unsupported trace_version {version} (this build reads <= {TRACE_VERSION})"
        )));
    }
    Ok(())
}

/// One request of a replayable trace, by benchmark name: the CLI resolves
/// `bench`/`size` back into a [`Computation`] plus deterministic input
/// buffers, so traces stay small and portable (no argument payloads).
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedRequest {
    pub bench: String,
    pub size: u64,
    /// Arrival offset in seconds from stream start
    /// ([`ServeRequest::arrival_offset`]).
    pub offset: f64,
    /// The deadline recorded for the request (explicit, or the resolved
    /// default of the recorded run).
    pub deadline: Option<f64>,
    /// Whether `deadline` was explicit on the request. A defaulted
    /// deadline is *not* baked into the replayed request — replay leaves
    /// it `None` and lets [`ServeOpts::deadline_default`] resolve it at
    /// admission, reproducing the recorded run's admission decisions
    /// exactly even if the default changes meaning.
    pub deadline_explicit: bool,
    pub priority: u32,
}

impl RecordedRequest {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("bench", Json::str(self.bench.as_str())),
            ("size", Json::num(self.size as f64)),
            ("offset", Json::num(self.offset)),
            ("deadline_explicit", Json::Bool(self.deadline_explicit)),
            ("priority", Json::num(self.priority as f64)),
        ];
        if let Some(d) = self.deadline {
            fields.push(("deadline", Json::num(d)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<RecordedRequest> {
        Ok(RecordedRequest {
            bench: v
                .get("bench")?
                .as_str()
                .ok_or_else(|| {
                    crate::error::Error::Kb("request 'bench' must be a string".into())
                })?
                .to_string(),
            size: v.get("size")?.as_u64().ok_or_else(|| {
                crate::error::Error::Kb("request 'size' must be an integer".into())
            })?,
            offset: v.get("offset").ok().and_then(|x| x.as_f64()).unwrap_or(0.0),
            deadline: v.get("deadline").ok().and_then(|x| x.as_f64()),
            deadline_explicit: v
                .get("deadline_explicit")
                .ok()
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            priority: v
                .get("priority")
                .ok()
                .and_then(|x| x.as_u64())
                .unwrap_or(0) as u32,
        })
    }

    /// The deadline to put on the replayed [`ServeRequest`]: explicit
    /// deadlines travel with the request, defaulted ones are re-resolved
    /// from the replayed opts.
    pub fn replay_deadline(&self) -> Option<f64> {
        if self.deadline_explicit {
            self.deadline
        } else {
            None
        }
    }
}

/// A replayable serve trace (DESIGN.md §2.13): the request mix (arrival
/// offsets, workload names, sizes, deadlines, priorities), the
/// [`ServeOpts`] — including the [`ExecProfile`] the run served under —
/// and a fig11-style background CPU load schedule. `marrow serve --record`
/// writes one; `marrow serve --replay` reconstructs the run from it.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayTrace {
    pub opts: ServeOpts,
    /// Piecewise-constant background CPU load, `(from_run, threads)`
    /// steps injected into the simulated machine's balancer
    /// ([`crate::sim::cpuload::LoadProfile`]).
    pub load: Vec<(u64, u32)>,
    pub requests: Vec<RecordedRequest>,
}

impl ReplayTrace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace_version", Json::num(TRACE_VERSION as f64)),
            ("opts", self.opts.to_json()),
            (
                "load",
                Json::arr(
                    self.load
                        .iter()
                        .map(|&(from, threads)| {
                            Json::arr(vec![
                                Json::num(from as f64),
                                Json::num(threads as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "requests",
                Json::arr(self.requests.iter().map(RecordedRequest::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ReplayTrace> {
        check_trace_version(v)?;
        let load = match v.get("load") {
            Ok(l) => l
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|step| {
                    let pair = step.as_arr().unwrap_or(&[]);
                    let from = pair.first().and_then(|x| x.as_u64()).unwrap_or(0);
                    let threads =
                        pair.get(1).and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                    (from, threads)
                })
                .collect(),
            Err(_) => Vec::new(),
        };
        let requests = v
            .get("requests")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(RecordedRequest::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ReplayTrace {
            opts: match v.get("opts") {
                Ok(o) => ServeOpts::from_json(o)?,
                Err(_) => ServeOpts::default(),
            },
            load,
            requests,
        })
    }

    /// Parse a trace file's text.
    pub fn parse(text: &str) -> Result<ReplayTrace> {
        ReplayTrace::from_json(&Json::parse(text)?)
    }
}

/// Width slack of the admission policy: among candidate subsets whose
/// predicted completion is within this factor of the best, the *narrowest*
/// (smallest capacity share) wins. A bounded solo slowdown buys free
/// devices for concurrent requests — EngineCL's co-execution result — and
/// a strongly CPU- or GPU-leaning request therefore leaves the other
/// device type to the rest of the stream even when the pool is idle.
///
/// The tradeoff is deliberate and bounded: on a *homogeneous* stream
/// (every request leaning the same way) the preferred subset serializes
/// the stream at up to `1/capacity` (≤ `WIDTH_SLACK`) of the whole-pool
/// per-request time while the other device idles — capacity held in
/// reserve for traffic that never comes. Streams known to be homogeneous
/// should keep `co_schedule` off (the default); under congestion the
/// wait term grows until the idle device's candidate wins and the stream
/// spills over, so the loss cannot compound unboundedly.
const WIDTH_SLACK: f64 = 1.25;

/// One admission decision (DESIGN.md §2.8).
struct Admission {
    mask: SlotMask,
    /// Estimated execution + migration seconds on the chosen subset — the
    /// wait later conflicting requests are charged while the reservation
    /// is held.
    est_secs: f64,
}

/// Drop guard clearing a session's slot mask on every exit path: a
/// panicking masked request must not leave the pooled session restricted
/// (or quarantined from learning) for whoever reuses the pool. Clears via
/// the poison-tolerant path so an unwind cannot double-panic.
struct MaskReset<'s, E: ExecEnv>(&'s Session<E>);

impl<E: ExecEnv> Drop for MaskReset<'_, E> {
    fn drop(&mut self) {
        self.0.clear_slot_mask_quiet();
    }
}

/// Price every candidate device subset for a request and pick the one
/// minimizing predicted completion: `wait` (conflicting admitted work) +
/// `base / capacity` (the KB cost estimate derated to the subset's share
/// of the tuned throughput) + `migration` (residency parked on excluded
/// devices). Ties within [`WIDTH_SLACK`] go to the narrowest subset.
fn admit<E: ExecEnv + Send>(
    session: &Session<E>,
    machine: &Machine,
    comp: &Computation,
    base_secs: f64,
    reservations: &SlotReservations,
) -> Admission {
    let cfg = comp
        .spec()
        .ok()
        .and_then(|(sct, w, _)| session.kb().derive(&sct.id(), w))
        .unwrap_or_else(|| super::baseline_config(machine));
    let base = base_secs.max(1e-9);
    let mut scored: Vec<(SlotMask, f64, f64, f64)> = Vec::new();
    for mask in candidate_masks(machine) {
        let cap = mask.capacity_frac(&cfg, machine);
        if cap <= 1e-9 {
            continue;
        }
        let exec = base / cap + session.mask_migration_secs(&mask);
        let wait = reservations.pending_secs(&mask);
        scored.push((mask, wait + exec, exec, cap));
    }
    let best = scored.iter().map(|s| s.1).fold(f64::INFINITY, f64::min);
    let (mask, _, est_secs, _) = scored
        .into_iter()
        .filter(|s| s.1 <= best * WIDTH_SLACK)
        .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
        .expect("the full mask always has capacity 1");
    Admission { mask, est_secs }
}

/// A pool of sessions over one shared knowledge base.
pub struct SessionPool<E: ExecEnv + Send> {
    sessions: Vec<Session<E>>,
}

impl<E: ExecEnv + Send> SessionPool<E> {
    /// Build a pool of `n` sessions from a factory; every session after
    /// the first is re-wired onto the first one's knowledge base.
    pub fn build<F: FnMut(usize) -> Session<E>>(n: usize, mut mk: F) -> SessionPool<E> {
        let mut sessions: Vec<Session<E>> = Vec::with_capacity(n.max(1));
        let mut shared: Option<Arc<RwLock<KnowledgeBase>>> = None;
        for i in 0..n.max(1) {
            let s = mk(i);
            let s = match &shared {
                None => {
                    shared = Some(s.shared_kb());
                    s
                }
                Some(kb) => s.with_shared_kb(kb.clone()),
            };
            sessions.push(s);
        }
        SessionPool { sessions }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn sessions(&self) -> &[Session<E>] {
        &self.sessions
    }

    /// The pool's shared knowledge base handle.
    pub fn shared_kb(&self) -> Arc<RwLock<KnowledgeBase>> {
        self.sessions[0].shared_kb()
    }

    /// Session counters summed over the pool (lifetime totals).
    fn summed_stats(&self) -> SessionStats {
        let mut stats = SessionStats::default();
        for s in &self.sessions {
            let st = s.stats();
            stats.runs += st.runs;
            stats.kb_hits += st.kb_hits;
            stats.warm_hits += st.warm_hits;
            stats.derived += st.derived;
            stats.built += st.built;
            stats.build_secs += st.build_secs;
            stats.pinned += st.pinned;
            stats.balance_ops += st.balance_ops;
            stats.unbalanced_runs += st.unbalanced_runs;
            stats.bytes_uploaded += st.bytes_uploaded;
            stats.bytes_downloaded += st.bytes_downloaded;
            stats.uploads_avoided += st.uploads_avoided;
            stats.uploads_avoided_bytes += st.uploads_avoided_bytes;
            stats.uploads_overlapped += st.uploads_overlapped;
            stats.uploads_overlapped_bytes += st.uploads_overlapped_bytes;
            stats.steal_migrations += st.steal_migrations;
            stats.idle_frac_sum += st.idle_frac_sum;
        }
        stats
    }

    /// Drain a request stream: up to `opts.concurrency` workers (bounded by
    /// the pool size) pull requests in order. The first error cancels the
    /// remaining stream and is returned.
    pub fn serve(&self, requests: &[ServeRequest], opts: &ServeOpts) -> Result<ServeReport> {
        let workers = opts.concurrency.clamp(1, self.sessions.len());
        // One profile application per pooled session (DESIGN.md §2.13):
        // every worker serves under the same pinned knobs, and each
        // session's stored profile records them for trace recording.
        if !opts.exec.is_empty() {
            for s in &self.sessions {
                s.apply_exec(&opts.exec);
            }
        }
        // Snapshot so the report's stats cover this run only, even when the
        // pool is reused across serve calls.
        let stats_before = self.summed_stats();
        let machine = self.sessions[0].machine();
        let full_mask = SlotMask::full(&machine);
        let reservations = SlotReservations::new();
        let timeline = VirtualTimeline::new();
        let head = Mutex::new(0usize);
        let batch_seq = AtomicUsize::new(0);
        let traces: Mutex<Vec<RequestTrace>> = Mutex::new(Vec::with_capacity(requests.len()));
        let failure: Mutex<Option<crate::error::Error>> = Mutex::new(None);

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for (w, session) in self.sessions.iter().take(workers).enumerate() {
                let head = &head;
                let batch_seq = &batch_seq;
                let traces = &traces;
                let failure = &failure;
                let machine = &machine;
                let full_mask = &full_mask;
                let reservations = &reservations;
                let timeline = &timeline;
                let opts = &*opts;
                scope.spawn(move || loop {
                    if failure.lock().unwrap().is_some() {
                        break;
                    }
                    let fail = |e: crate::error::Error| {
                        let mut f = failure.lock().unwrap();
                        if f.is_none() {
                            *f = Some(e);
                        }
                    };
                    let Some((start, len)) =
                        Self::claim_batch(head, requests, opts, session)
                    else {
                        break;
                    };
                    let batch = batch_seq.fetch_add(1, Ordering::SeqCst);
                    let members = &requests[start..start + len];
                    // Arrival pacing (trace replay, DESIGN.md §2.13): a
                    // request that "arrives" in the future is held until
                    // its recorded offset — latency is measured from
                    // arrival, so a replayed stream reports what the
                    // original clients observed.
                    let due = members[0].arrival_offset;
                    let elapsed = t0.elapsed().as_secs_f64();
                    if due > elapsed {
                        std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                    }
                    let claimed = Instant::now();
                    // Admission (DESIGN.md §2.8/§2.10): price the batch as
                    // one fused drain on every device subset and reserve
                    // the cheapest — one reservation per batch, not per
                    // member; the guard releases on every exit path,
                    // including unwinds.
                    let admission = if opts.co_schedule {
                        match Self::batch_admission_for(
                            session,
                            machine,
                            members,
                            traces,
                            reservations,
                        ) {
                            Ok(a) => Some(a),
                            Err(e) => {
                                fail(e);
                                break;
                            }
                        }
                    } else {
                        None
                    };
                    let _guard = admission
                        .as_ref()
                        .map(|a| reservations.acquire(a.mask.clone(), a.est_secs));
                    let mask = admission.map(|a| a.mask);
                    // Learning quarantine (DESIGN.md §2.10): only a
                    // *partial* reservation skews slot times, so only a
                    // partial mask is installed — a batch admitted onto
                    // the whole machine keeps feeding the monitor and the
                    // shared knowledge base.
                    let restricted = mask.as_ref().is_some_and(|m| m != full_mask);
                    let _mask_reset = if restricted {
                        session.set_slot_mask(mask.clone());
                        Some(MaskReset(session))
                    } else {
                        None
                    };

                    // Drain the members back to back: each runs its own
                    // graph with its own arguments (bit-identical to a
                    // solo run), while admission, reservation, the pace
                    // floor, and the timeline booking are paid once.
                    let mut drained: Vec<(ConfigOrigin, ExecOutcome, f64, f64)> =
                        Vec::with_capacity(len);
                    let mut failed = false;
                    for req in members {
                        let waited = claimed.elapsed().as_secs_f64();
                        let t_run = Instant::now();
                        match session.run(&req.comp, &req.args) {
                            Ok(out) => drained.push((
                                out.origin,
                                out.exec,
                                waited,
                                t_run.elapsed().as_secs_f64(),
                            )),
                            Err(e) => {
                                fail(e);
                                failed = true;
                                break;
                            }
                        }
                    }
                    if !drained.is_empty() {
                        if opts.pace > 0.0 {
                            // The pace floor stands in for per-request
                            // host-side handling and holds the
                            // reservation; a batch pays it once — the
                            // wall-clock side of the amortization.
                            std::thread::sleep(Duration::from_secs_f64(opts.pace));
                        }
                        // One booking at the fused makespan: the batch's
                        // members overlap on the device timeline instead
                        // of serializing (DESIGN.md §2.10).
                        let execs: Vec<&ExecOutcome> =
                            drained.iter().map(|d| &d.1).collect();
                        timeline.book(
                            mask.as_ref().unwrap_or(full_mask),
                            ExecOutcome::fused_total(&execs),
                        );
                        let latency = claimed.elapsed().as_secs_f64();
                        let (done_before, done) = {
                            let mut tr = traces.lock().unwrap();
                            let before = tr.len();
                            for (k, (origin, exec, waited, drain)) in
                                drained.iter().enumerate()
                            {
                                let explicit = members[k].deadline.is_some();
                                let deadline = members[k]
                                    .deadline
                                    .or(opts.deadline_default);
                                tr.push(RequestTrace {
                                    index: start + k,
                                    worker: w,
                                    latency,
                                    admit_wait: *waited,
                                    drain: *drain,
                                    origin: *origin,
                                    exec_total: exec.total,
                                    mask: mask.clone(),
                                    batch,
                                    batch_size: len,
                                    deadline_missed: deadline
                                        .is_some_and(|d| latency > d),
                                    deadline_defaulted: !explicit
                                        && deadline.is_some(),
                                });
                            }
                            (before, tr.len())
                        };
                        // Periodic durability: commit staged profiles and
                        // absorb foreign segments mid-run, so a crash
                        // loses at most ~`sync_every` requests' learning
                        // (DESIGN.md §2.9). Batches land several requests
                        // at once, so sync on every crossing of the
                        // interval, not on exact multiples.
                        let sync_every = opts.store_sync_every;
                        if sync_every > 0
                            && done_before / sync_every != done / sync_every
                        {
                            if let Err(e) = session.sync_kb() {
                                fail(e);
                                break;
                            }
                        }
                    }
                    if failed {
                        break;
                    }
                });
            }
        });
        let wall_secs = t0.elapsed().as_secs_f64().max(1e-9);

        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        // Final durability point: whatever the stream learned is committed
        // before the report is handed back (no-op without store backing;
        // the KB is shared, so any one session flushes for the pool).
        self.sessions[0].sync_kb()?;
        let mut traces = traces.into_inner().unwrap();
        traces.sort_by_key(|t| t.index);
        let latencies: Vec<f64> = traces.iter().map(|t| t.latency).collect();
        let mean_latency = if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().sum::<f64>() / latencies.len() as f64
        };
        let admit_waits: Vec<f64> = traces.iter().map(|t| t.admit_wait).collect();
        let drains: Vec<f64> = traces.iter().map(|t| t.drain).collect();
        let batches = traces
            .iter()
            .map(|t| t.batch)
            .collect::<std::collections::HashSet<_>>()
            .len();
        let deadline_misses = traces.iter().filter(|t| t.deadline_missed).count();
        let after = self.summed_stats();
        let stats = SessionStats {
            runs: after.runs - stats_before.runs,
            kb_hits: after.kb_hits - stats_before.kb_hits,
            warm_hits: after.warm_hits - stats_before.warm_hits,
            derived: after.derived - stats_before.derived,
            built: after.built - stats_before.built,
            build_secs: after.build_secs - stats_before.build_secs,
            pinned: after.pinned - stats_before.pinned,
            balance_ops: after.balance_ops - stats_before.balance_ops,
            unbalanced_runs: after.unbalanced_runs - stats_before.unbalanced_runs,
            bytes_uploaded: after.bytes_uploaded - stats_before.bytes_uploaded,
            bytes_downloaded: after.bytes_downloaded - stats_before.bytes_downloaded,
            uploads_avoided: after.uploads_avoided - stats_before.uploads_avoided,
            uploads_avoided_bytes: after.uploads_avoided_bytes - stats_before.uploads_avoided_bytes,
            uploads_overlapped: after.uploads_overlapped - stats_before.uploads_overlapped,
            uploads_overlapped_bytes: after.uploads_overlapped_bytes
                - stats_before.uploads_overlapped_bytes,
            steal_migrations: after.steal_migrations - stats_before.steal_migrations,
            idle_frac_sum: after.idle_frac_sum - stats_before.idle_frac_sum,
        };
        Ok(ServeReport {
            completed: traces.len(),
            concurrency: workers,
            wall_secs,
            requests_per_sec: traces.len() as f64 / wall_secs,
            // Percentiles index into duration-sorted samples — never the
            // completion-ordered trace (`percentile` sorts a copy, so a
            // fast request finishing last cannot leak into p99; the
            // known-distribution unit test below pins this invariant).
            p50_latency: percentile(&latencies, 50.0),
            p99_latency: percentile(&latencies, 99.0),
            mean_latency,
            p50_admit_wait: percentile(&admit_waits, 50.0),
            p99_admit_wait: percentile(&admit_waits, 99.0),
            p50_drain: percentile(&drains, 50.0),
            p99_drain: percentile(&drains, 99.0),
            batches,
            deadline_misses,
            co_scheduled: opts.co_schedule,
            virtual_makespan: timeline.makespan(),
            stats,
            traces,
        })
    }

    /// Claim the next batch off the stream head: the first unclaimed
    /// request, extended while the following requests stay batchable
    /// (sync-free stage programs, [`batchable_bytes`]), the size and byte
    /// budgets hold, and the projected fused completion stays inside both
    /// the (priority-scaled) batch window and every member's deadline
    /// slack (DESIGN.md §2.10). Estimates come from the shared KB
    /// ([`COLD_EST_SECS`] for cold members, so a cold stream closes on
    /// the size/byte budgets alone). Claims are consecutive: request
    /// order is preserved and no request is skipped over.
    fn claim_batch(
        head: &Mutex<usize>,
        requests: &[ServeRequest],
        opts: &ServeOpts,
        session: &Session<E>,
    ) -> Option<(usize, usize)> {
        let mut head = head.lock().unwrap();
        let start = *head;
        if start >= requests.len() {
            return None;
        }
        let mut len = 1usize;
        if opts.batch_max > 1 {
            if let Some(first_bytes) = batchable_bytes(&requests[start].comp) {
                let est = |i: usize| {
                    session
                        .kb_estimate(&requests[i].comp)
                        .unwrap_or(COLD_EST_SECS)
                };
                let deadline = |r: &ServeRequest| {
                    r.deadline.or(opts.deadline_default).unwrap_or(f64::INFINITY)
                };
                let solo = est(start);
                let mut ests = vec![solo];
                let mut bytes = first_bytes;
                let mut slack = deadline(&requests[start]);
                let mut top_priority = requests[start].priority;
                while len < opts.batch_max && start + len < requests.len() {
                    let cand = &requests[start + len];
                    // Arrival-gap close (trace replay, DESIGN.md §2.13):
                    // a candidate arriving more than the batch window
                    // after the head member would force the head to wait
                    // for it — the batch closes instead, so replayed
                    // arrival gaps reproduce the recorded run's batch
                    // boundaries deterministically (offsets are data, not
                    // wall clock).
                    if cand.arrival_offset - requests[start].arrival_offset
                        > opts.batch_window
                    {
                        break;
                    }
                    let Some(cand_bytes) = batchable_bytes(&cand.comp) else {
                        break;
                    };
                    if bytes.saturating_add(cand_bytes) > opts.batch_bytes {
                        break;
                    }
                    ests.push(est(start + len));
                    let fused = pack_estimate(&ests);
                    let priority = top_priority.max(cand.priority);
                    let window = opts.batch_window / (1.0 + priority as f64);
                    let cand_slack = slack.min(deadline(cand));
                    // The oldest member absorbs the full stretch over its
                    // solo estimate; any member's exhausted deadline
                    // slack closes the batch (SLO-aware close).
                    if fused - solo > window || fused > cand_slack {
                        ests.pop();
                        break;
                    }
                    bytes += cand_bytes;
                    slack = cand_slack;
                    top_priority = priority;
                    len += 1;
                }
            }
        }
        *head = start + len;
        Some((start, len))
    }

    /// Per-member admission base: KB cost estimate (resolving the
    /// configuration first on a cold KB, so the profile build runs on the
    /// *whole* machine — a reservation mask must never leak into a stored
    /// profile), falling back to the mean observed execution time of this
    /// serve run. A cold request resolved here is re-resolved inside
    /// [`Session::run`] as a KB hit, so co-scheduled cold starts book
    /// `built + 1` *and* `kb_hits + 1` — compare hit-rates across modes
    /// accordingly.
    fn member_base(
        session: &Session<E>,
        req: &ServeRequest,
        traces: &Mutex<Vec<RequestTrace>>,
    ) -> Result<f64> {
        let base = match session.kb_estimate(&req.comp) {
            Some(t) => Some(t),
            None => {
                session.resolve_config(&req.comp, &req.args)?;
                session.kb_estimate(&req.comp)
            }
        };
        Ok(base.unwrap_or_else(|| {
            let tr = traces.lock().unwrap();
            if tr.is_empty() {
                COLD_EST_SECS
            } else {
                tr.iter().map(|t| t.exec_total).sum::<f64>() / tr.len() as f64
            }
        }))
    }

    /// The co-scheduling admission pipeline for one batch: price every
    /// member ([`Self::member_base`]), then ask the KB for the
    /// fused-batch estimate — a batch is priced as *one fused drain*,
    /// never the sum of its members (DESIGN.md §2.10);
    /// [`pack_estimate`] over the solo bases stands in when any member is
    /// cold — and run the subset pricing of [`admit`] with the critical
    /// (most expensive) member's configuration, whose device leaning
    /// dominates the fused drain's shape.
    fn batch_admission_for(
        session: &Session<E>,
        machine: &Machine,
        members: &[ServeRequest],
        traces: &Mutex<Vec<RequestTrace>>,
        reservations: &SlotReservations,
    ) -> Result<Admission> {
        let mut bases = Vec::with_capacity(members.len());
        for req in members {
            bases.push(Self::member_base(session, req, traces)?);
        }
        let fused = {
            let mut ids = Vec::with_capacity(members.len());
            let mut loads = Vec::with_capacity(members.len());
            for req in members {
                let (sct, w, _) = req.comp.spec()?;
                ids.push(sct.id());
                loads.push(w);
            }
            let items: Vec<(&str, &Workload)> = ids
                .iter()
                .map(String::as_str)
                .zip(loads.iter().copied())
                .collect();
            session.kb().estimate_batch(&items)
        }
        .unwrap_or_else(|| pack_estimate(&bases));
        let critical = bases
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(admit(
            session,
            machine,
            &members[critical].comp,
            fused,
            reservations,
        ))
    }
}

/// Cold-KB fallback estimate for batch-close decisions (seconds). Keeps
/// the window math defined on an empty knowledge base; a cold stream
/// effectively closes batches on the size and byte budgets alone.
const COLD_EST_SECS: f64 = 1e-3;

/// Whether a request can ride in a batch, and its approximate working-set
/// bytes charged against [`ServeOpts::batch_bytes`]. `None` marks the
/// request solo-only: a malformed spec, or a stage program with global
/// sync points — [`fuse_graphs`](crate::decompose::graph::fuse_graphs)
/// rejects sync nodes because a fused graph has one final-output slot per
/// launch, so loops and reductions always drain alone ([`fusable`]).
fn batchable_bytes(comp: &Computation) -> Option<u64> {
    let (sct, w, units) = comp.spec().ok()?;
    if !fusable(sct) {
        return None;
    }
    let elem: u64 = if w.double_precision { 8 } else { 4 };
    Some(units.saturating_mul(elem) + comp.get_copy_bytes() as u64)
}

/// Serve a request stream over a pool of simulated sessions for `machine`
/// (one per admitted request), sharing one knowledge base.
pub fn serve_simulated(
    machine: &Machine,
    seed: u64,
    requests: &[ServeRequest],
    opts: &ServeOpts,
) -> Result<ServeReport> {
    let pool = SessionPool::build(opts.concurrency.max(1), |i| {
        Session::simulated(machine.clone(), seed + i as u64)
    });
    pool.serve(requests, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::kb::mk_profile;
    use crate::platform::cpu::FissionLevel;
    use crate::platform::device::i7_hd7950;
    use crate::scheduler::SimEnv;

    fn requests(n: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|_| ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))))
            .collect()
    }

    #[test]
    fn pool_shares_one_kb_across_sessions() {
        let pool = SessionPool::build(3, |i| Session::simulated(i7_hd7950(1), 40 + i as u64));
        let reqs = requests(6);
        let report = pool
            .serve(
                &reqs,
                &ServeOpts {
                    concurrency: 3,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(report.completed, 6);
        // One cold start warms the whole pool: exactly one build (plus any
        // same-instant racers), and the shared KB holds one profile.
        assert_eq!(pool.shared_kb().read().unwrap().len(), 1);
        assert!(report.stats.kb_hits + report.stats.derived >= 3);
        // Without co-scheduling every request books the whole pool: the
        // virtual makespan is the serialized sum of execution times.
        assert!(!report.co_scheduled);
        let sum: f64 = report.traces.iter().map(|t| t.exec_total).sum();
        assert!((report.virtual_makespan - sum).abs() <= 1e-9 * sum.max(1.0));
        assert!(report.traces.iter().all(|t| t.mask.is_none()));
    }

    #[test]
    fn serve_reports_latency_percentiles() {
        let reqs = requests(8);
        let report = serve_simulated(
            &i7_hd7950(1),
            7,
            &reqs,
            &ServeOpts {
                concurrency: 2,
                pace: 0.002,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.requests_per_sec > 0.0);
        assert!(report.p50_latency >= 0.002);
        assert!(report.p99_latency >= report.p50_latency);
        // Every request is accounted for exactly once, in stream order.
        let idx: Vec<usize> = report.traces.iter().map(|t| t.index).collect();
        assert_eq!(idx, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn percentiles_index_duration_sorted_samples() {
        // A known distribution handed over in *reverse completion order*:
        // the percentiles must come from the sorted durations, so p50 of
        // 1..=101 is exactly 51 and p99 exactly 100 — not whatever landed
        // at those completion indices.
        let completion_order: Vec<f64> = (1..=101).rev().map(|i| i as f64).collect();
        let mut by_duration = completion_order.clone();
        by_duration.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((percentile(&by_duration, 50.0) - 51.0).abs() < 1e-12);
        assert!((percentile(&by_duration, 99.0) - 100.0).abs() < 1e-12);
        // And the serve path reports exactly these sorted-index values.
        let reqs = requests(3);
        let report = serve_simulated(&i7_hd7950(1), 3, &reqs, &ServeOpts::default()).unwrap();
        let mut lat: Vec<f64> = report.traces.iter().map(|t| t.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(report.p50_latency.to_bits(), percentile(&lat, 50.0).to_bits());
        assert_eq!(report.p99_latency.to_bits(), percentile(&lat, 99.0).to_bits());
    }

    #[test]
    fn concurrency_is_capped_by_pool_size() {
        let pool = SessionPool::build(2, |i| Session::simulated(i7_hd7950(1), i as u64));
        let report = pool
            .serve(
                &requests(4),
                &ServeOpts {
                    concurrency: 16,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(report.concurrency, 2);
        assert_eq!(report.completed, 4);
    }

    /// A session over `machine` whose KB already holds a profile pinning
    /// `cpu_share` for `comp` — the admission sees a tuned split without
    /// running Algorithm 1.
    fn seeded_session(comp: &Computation, cpu_share: f64, best: f64) -> Session<SimEnv> {
        let s = Session::simulated(i7_hd7950(1), 21);
        let (sct, w, _) = comp.spec().unwrap();
        s.kb_mut().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            cpu_share,
            best,
        ));
        s
    }

    #[test]
    fn admission_sends_leaning_requests_to_their_device() {
        let machine = i7_hd7950(1);
        let cpu_comp = Computation::from(workloads::saxpy(1 << 20));
        let gpu_comp = Computation::from(workloads::saxpy(1 << 21));
        let reservations = SlotReservations::new();
        // CPU-leaning (tuned split 90% CPU): the CPU subset is within the
        // width slack of the full pool and narrower, so it wins.
        let s = seeded_session(&cpu_comp, 0.9, 1.0);
        let a = admit(&s, &machine, &cpu_comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::cpu_only(&machine), "got {}", a.mask);
        // GPU-leaning: the GPU subset wins symmetrically.
        let s = seeded_session(&gpu_comp, 0.1, 1.0);
        let a = admit(&s, &machine, &gpu_comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::single_gpu(&machine, 0), "got {}", a.mask);
        // A balanced request keeps the whole pool: halving the hardware
        // would double it, far past the slack.
        let s = seeded_session(&cpu_comp, 0.5, 1.0);
        let a = admit(&s, &machine, &cpu_comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::full(&machine), "got {}", a.mask);
    }

    #[test]
    fn admission_waits_steer_around_held_devices() {
        let machine = i7_hd7950(1);
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let s = seeded_session(&comp, 0.1, 1.0); // GPU-leaning
        let reservations = SlotReservations::new();
        // GPU held for a long time: even a GPU-leaning request is better
        // off on the free CPU than queued behind 100 s of GPU work.
        let _gpu = reservations
            .try_acquire(SlotMask::all_gpus(&machine), 100.0)
            .unwrap();
        let a = admit(&s, &machine, &comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::cpu_only(&machine), "got {}", a.mask);
    }

    #[test]
    fn batching_coalesces_requests_and_keeps_results_identical() {
        let machine = i7_hd7950(1);
        let mk = |seed: u64| {
            let pool = SessionPool::build(2, |i| {
                Session::simulated(machine.clone(), seed + i as u64).with_max_dev(10.0)
            });
            let (sct, w, _) = Computation::from(workloads::saxpy(1 << 20))
                .spec()
                .map(|(s, w, u)| (s.id(), w.clone(), u))
                .unwrap();
            pool.shared_kb().write().unwrap().store(mk_profile(
                &sct,
                w,
                FissionLevel::L2,
                vec![4],
                0.5,
                1e-3,
            ));
            pool
        };
        let reqs = requests(8);
        let solo = mk(80)
            .serve(&reqs, &ServeOpts::default())
            .unwrap();
        let batched = mk(80)
            .serve(
                &reqs,
                &ServeOpts {
                    batch_max: 4,
                    batch_window: 1.0,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(solo.completed, 8);
        assert_eq!(batched.completed, 8);
        // Solo: one batch per request. Batched: the stream coalesces.
        assert_eq!(solo.batches, 8);
        assert!(solo.traces.iter().all(|t| t.batch_size == 1));
        assert!(
            batched.batches < 8,
            "expected coalescing, got {} batches",
            batched.batches
        );
        assert!(batched.traces.iter().any(|t| t.batch_size > 1));
        // Bit-identical per-request results: batching changes scheduling,
        // never execution (both pools are seeded identically and frozen
        // against ABS adaptation).
        for (s, b) in solo.traces.iter().zip(batched.traces.iter()) {
            assert_eq!(s.index, b.index);
            assert_eq!(s.exec_total.to_bits(), b.exec_total.to_bits());
        }
        // The latency split accounts for the wait batching introduces:
        // admit_wait never exceeds end-to-end latency.
        for t in batched.traces.iter() {
            assert!(t.admit_wait <= t.latency + 1e-12);
            assert!(t.drain >= 0.0);
            assert!(!t.deadline_missed, "no deadlines were set");
        }
    }

    #[test]
    fn batch_close_honors_deadline_priority_and_compatibility() {
        let session = Session::simulated(i7_hd7950(1), 91);
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let (sct, w, _) = comp.spec().unwrap();
        session.kb_mut().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            0.5,
            1e-2,
        ));
        let opts = ServeOpts {
            batch_max: 8,
            batch_window: 1.0,
            ..Default::default()
        };
        // Wide window, no deadlines: the whole stream fuses to batch_max.
        let reqs = requests(8);
        let head = Mutex::new(0usize);
        let claimed = SessionPool::claim_batch(&head, &reqs, &opts, &session).unwrap();
        assert_eq!(claimed, (0, 8));
        // A member whose deadline is below the fused estimate closes the
        // batch before that member's slack is overrun: with a 10 ms solo
        // estimate, a 15 ms deadline admits the first fusion step
        // (pack of two = 16 ms > 15 ms), so the batch stays solo.
        let tight: Vec<ServeRequest> = (0..4)
            .map(|_| {
                ServeRequest::from(Computation::from(workloads::saxpy(1 << 20)))
                    .with_deadline(0.015)
            })
            .collect();
        let head = Mutex::new(0usize);
        let claimed = SessionPool::claim_batch(&head, &tight, &opts, &session).unwrap();
        assert_eq!(claimed, (0, 1), "deadline slack must close the batch");
        // Priority shrinks the tolerated window the same way: a high
        // priority member scales a generous window below the pack stretch.
        let urgent: Vec<ServeRequest> = (0..4)
            .map(|_| {
                ServeRequest::from(Computation::from(workloads::saxpy(1 << 20)))
                    .with_priority(1_000_000)
            })
            .collect();
        let narrow = ServeOpts {
            batch_max: 8,
            batch_window: 1.0,
            ..Default::default()
        };
        let head = Mutex::new(0usize);
        let claimed = SessionPool::claim_batch(&head, &urgent, &narrow, &session).unwrap();
        assert_eq!(claimed, (0, 1), "priority must shrink the window");
        // A sync-bearing program (global-sync loop) never rides in a
        // batch: the claim stops in front of it, then serves it solo.
        let mixed = vec![
            ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))),
            ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))),
            ServeRequest::from(Computation::from(workloads::nbody(1 << 10, 3))),
            ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))),
        ];
        let head = Mutex::new(0usize);
        assert_eq!(
            SessionPool::claim_batch(&head, &mixed, &opts, &session).unwrap(),
            (0, 2)
        );
        assert_eq!(
            SessionPool::claim_batch(&head, &mixed, &opts, &session).unwrap(),
            (2, 1),
            "sync programs drain solo"
        );
        assert_eq!(
            SessionPool::claim_batch(&head, &mixed, &opts, &session).unwrap(),
            (3, 1)
        );
        assert!(SessionPool::claim_batch(&head, &mixed, &opts, &session).is_none());
    }

    #[test]
    fn arrival_gaps_close_batches_and_pace_claims() {
        let session = Session::simulated(i7_hd7950(1), 93);
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let (sct, w, _) = comp.spec().unwrap();
        session.kb_mut().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            0.5,
            1e-4,
        ));
        let opts = ServeOpts {
            batch_max: 8,
            batch_window: 2e-3,
            ..Default::default()
        };
        // Four requests, the last arriving 50 ms after the first three:
        // the gap exceeds the 2 ms window, so the batch closes at 3.
        let reqs: Vec<ServeRequest> = (0..4)
            .map(|i| {
                ServeRequest::from(Computation::from(workloads::saxpy(1 << 20)))
                    .with_arrival_offset(if i == 3 { 0.05 } else { 0.0 })
            })
            .collect();
        let head = Mutex::new(0usize);
        assert_eq!(
            SessionPool::claim_batch(&head, &reqs, &opts, &session).unwrap(),
            (0, 3),
            "the arrival gap must close the batch"
        );
        assert_eq!(
            SessionPool::claim_batch(&head, &reqs, &opts, &session).unwrap(),
            (3, 1)
        );
        // End to end, the late request's claim waits for its arrival.
        let report = serve_simulated(&i7_hd7950(1), 93, &reqs, &opts).unwrap();
        assert_eq!(report.completed, 4);
        assert!(
            report.wall_secs >= 0.05,
            "the stream cannot finish before its last arrival"
        );
    }

    #[test]
    fn serve_report_round_trips_through_json() {
        let reqs: Vec<ServeRequest> = requests(3)
            .into_iter()
            .enumerate()
            .map(|(i, r)| if i == 1 { r.with_deadline(0.5) } else { r })
            .collect();
        let report = serve_simulated(
            &i7_hd7950(1),
            23,
            &reqs,
            &ServeOpts {
                deadline_default: Some(10.0),
                ..Default::default()
            },
        )
        .unwrap();
        let text = report.to_json().to_string();
        let back = ServeReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.completed, report.completed);
        assert_eq!(back.traces.len(), report.traces.len());
        assert_eq!(
            back.virtual_makespan.to_bits(),
            report.virtual_makespan.to_bits()
        );
        assert_eq!(back.stats.runs, report.stats.runs);
        for (a, b) in report.traces.iter().zip(&back.traces) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.origin, b.origin);
            assert_eq!(a.exec_total.to_bits(), b.exec_total.to_bits());
            assert_eq!(a.deadline_defaulted, b.deadline_defaulted);
        }
        // The explicit deadline is distinguishable from the defaulted ones.
        assert!(!back.traces[1].deadline_defaulted);
        assert!(back.traces[0].deadline_defaulted && back.traces[2].deadline_defaulted);
        // Newer schema versions are a clean error.
        let newer = text.replacen("\"trace_version\": 1", "\"trace_version\": 99", 1);
        assert!(ServeReport::from_json(&Json::parse(&newer).unwrap()).is_err());
    }

    #[test]
    fn replay_trace_round_trips_through_json() {
        let trace = ReplayTrace {
            opts: ServeOpts {
                concurrency: 3,
                pace: 1e-3,
                exec: ExecProfile::new()
                    .tasks_per_slot(8)
                    .drain_mode(crate::scheduler::DrainMode::Barrier),
                batch_max: 4,
                deadline_default: Some(0.02),
                ..Default::default()
            },
            load: vec![(0, 0), (16, 6)],
            requests: vec![
                RecordedRequest {
                    bench: "spmv".into(),
                    size: 1024,
                    offset: 0.0,
                    deadline: None,
                    deadline_explicit: false,
                    priority: 0,
                },
                RecordedRequest {
                    bench: "saxpy".into(),
                    size: 1 << 20,
                    offset: 0.004,
                    deadline: Some(0.015),
                    deadline_explicit: true,
                    priority: 2,
                },
            ],
        };
        let back = ReplayTrace::parse(&trace.to_json().to_string_pretty()).unwrap();
        assert_eq!(back, trace);
        // Defaulted deadlines are re-resolved at replay, explicit ones
        // travel with the request.
        assert_eq!(back.requests[0].replay_deadline(), None);
        assert_eq!(back.requests[1].replay_deadline(), Some(0.015));
        // A versionless document is rejected.
        assert!(ReplayTrace::parse("{\"requests\": []}").is_err());
    }

    #[test]
    fn replay_is_deterministic_in_virtual_time() {
        // The replay acceptance bar: two serves of the same stream from
        // identically seeded pools produce bit-identical virtual
        // makespans and batch shapes (virtual time has no wall-clock
        // noise; KB state is the only other input, and it starts equal).
        let reqs: Vec<ServeRequest> = (0..6)
            .map(|i| {
                ServeRequest::from(Computation::from(workloads::saxpy(1 << 20)))
                    .with_arrival_offset(i as f64 * 1e-4)
            })
            .collect();
        let opts = ServeOpts {
            concurrency: 2,
            batch_max: 4,
            batch_window: 1.0,
            ..Default::default()
        };
        let mk = || {
            let pool =
                SessionPool::build(2, |i| Session::simulated(i7_hd7950(1), 70 + i as u64));
            let comp = Computation::from(workloads::saxpy(1 << 20));
            let (sct, w, _) = comp.spec().unwrap();
            pool.shared_kb().write().unwrap().store(mk_profile(
                &sct.id(),
                w.clone(),
                FissionLevel::L2,
                vec![4],
                0.5,
                1e-3,
            ));
            pool
        };
        let a = mk().serve(&reqs, &opts).unwrap();
        let b = mk().serve(&reqs, &opts).unwrap();
        assert_eq!(a.virtual_makespan.to_bits(), b.virtual_makespan.to_bits());
        assert_eq!(a.batches, b.batches);
        let shape = |r: &ServeReport| {
            r.traces.iter().map(|t| (t.index, t.batch_size)).collect::<Vec<_>>()
        };
        assert_eq!(shape(&a), shape(&b));
    }

    #[test]
    fn deadline_misses_are_reported() {
        // A 2 ms pace floor against a 1 µs deadline: every request misses.
        let reqs: Vec<ServeRequest> = requests(3);
        let report = serve_simulated(
            &i7_hd7950(1),
            17,
            &reqs,
            &ServeOpts {
                pace: 0.002,
                deadline_default: Some(1e-6),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.deadline_misses, 3);
        assert!(report.traces.iter().all(|t| t.deadline_missed));
        // Deadline-free requests never miss.
        let report = serve_simulated(&i7_hd7950(1), 17, &reqs, &ServeOpts::default()).unwrap();
        assert_eq!(report.deadline_misses, 0);
    }

    #[test]
    fn co_scheduled_serve_records_masks_and_overlapping_makespan() {
        let machine = i7_hd7950(1);
        let cpu_comp = Computation::from(workloads::saxpy(1 << 20));
        let gpu_comp = Computation::from(workloads::saxpy(1 << 21));
        let pool = SessionPool::build(2, |i| Session::simulated(machine.clone(), 60 + i as u64));
        for comp in [(&cpu_comp, 0.9), (&gpu_comp, 0.1)] {
            let (sct, w, _) = comp.0.spec().unwrap();
            pool.shared_kb().write().unwrap().store(mk_profile(
                &sct.id(),
                w.clone(),
                FissionLevel::L2,
                vec![4],
                comp.1,
                1e-3,
            ));
        }
        let reqs = vec![
            ServeRequest::from(cpu_comp),
            ServeRequest::from(gpu_comp),
        ];
        let report = pool
            .serve(
                &reqs,
                &ServeOpts {
                    concurrency: 2,
                    co_schedule: true,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(report.completed, 2);
        assert!(report.co_scheduled);
        assert!(report.traces.iter().all(|t| t.mask.is_some()));
        // Disjoint subsets overlap on the virtual timeline: the combined
        // makespan is below the serialized sum.
        let sum: f64 = report.traces.iter().map(|t| t.exec_total).sum();
        assert!(
            report.virtual_makespan < sum,
            "makespan {} must undercut the serialized sum {}",
            report.virtual_makespan,
            sum
        );
        assert!(report.virtual_req_per_sec() > 0.0);
        // The pool is reusable afterwards: no mask leaks past the request.
        let again = pool.serve(&requests(2), &ServeOpts::default()).unwrap();
        assert_eq!(again.completed, 2);
    }
}
