//! The multi-request serve path: a pool of sessions sharing one knowledge
//! base drains a stream of requests under an admission cap — the first
//! building block of the ROADMAP's "heavy traffic" north star.
//!
//! [`SessionPool`] owns N [`Session`]s (one backend each — the paper's
//! one-machine contract) wired to a single shared KB, so the first cold
//! start warms every worker: whichever session builds a profile, the rest
//! resolve the same computation as KB hits. [`SessionPool::serve`] spawns
//! one scoped worker thread per session; workers pull requests off a shared
//! cursor until the stream drains, recording per-request latency for the
//! p50/p99 report.
//!
//! Analytic backends price an execution and return immediately, which
//! makes a throughput number meaningless; [`ServeOpts::pace`] inserts a
//! fixed per-request service floor (sleep) that stands in for device
//! occupancy, so requests/sec measures genuine admission-cap scaling. Real
//! backends leave `pace` at 0.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::kb::KnowledgeBase;
use crate::platform::device::Machine;
use crate::runtime::exec::RequestArgs;
use crate::scheduler::{DrainMode, ExecEnv};
use crate::session::{Computation, ConfigOrigin, Session, SessionStats};
use crate::util::stats::percentile;

/// One queued request: a computation plus its arguments.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub comp: Computation,
    pub args: RequestArgs,
}

impl From<Computation> for ServeRequest {
    fn from(comp: Computation) -> ServeRequest {
        ServeRequest {
            comp,
            args: RequestArgs::default(),
        }
    }
}

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Admission cap: how many requests may be in flight at once (bounded
    /// by the pool size).
    pub concurrency: usize,
    /// Per-request service floor in seconds (see module docs). 0 disables.
    pub pace: f64,
    /// Override the stealable-tasks-per-slot knob on every pooled session
    /// (`--tasks-per-slot`); `None` keeps the backend default.
    pub tasks_per_slot: Option<u32>,
    /// Override the drain mode on every pooled session (`--drain`);
    /// `None` keeps the backend default ([`DrainMode::Dataflow`]).
    pub drain_mode: Option<DrainMode>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            concurrency: 1,
            pace: 0.0,
            tasks_per_slot: None,
            drain_mode: None,
        }
    }
}

/// One served request's record.
#[derive(Clone, Copy, Debug)]
pub struct RequestTrace {
    /// Index into the request stream.
    pub index: usize,
    /// Which pool worker served it.
    pub worker: usize,
    /// Wall seconds from admission to completion (including the pace floor).
    pub latency: f64,
    pub origin: ConfigOrigin,
    /// The execution's own completion time.
    pub exec_total: f64,
}

/// Aggregate outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    pub concurrency: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mean_latency: f64,
    /// Session counters for this serve run (pool-summed delta, so reusing
    /// a pool across serve calls still reports per-run numbers).
    pub stats: SessionStats,
    pub traces: Vec<RequestTrace>,
}

impl ServeReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests in {:.3}s @ concurrency {} -> {:.1} req/s \
             (p50 {:.2}ms, p99 {:.2}ms; {} kb hits, {} built, {} derived; \
             {:.1} MB uploaded, {} uploads avoided, {} steal migrations; \
             mean slot idle {:.1}%)",
            self.completed,
            self.wall_secs,
            self.concurrency,
            self.requests_per_sec,
            self.p50_latency * 1e3,
            self.p99_latency * 1e3,
            self.stats.kb_hits,
            self.stats.built,
            self.stats.derived,
            self.stats.bytes_uploaded as f64 / 1e6,
            self.stats.uploads_avoided,
            self.stats.steal_migrations,
            self.stats.mean_idle_pct()
        )
    }
}

/// A pool of sessions over one shared knowledge base.
pub struct SessionPool<E: ExecEnv + Send> {
    sessions: Vec<Session<E>>,
}

impl<E: ExecEnv + Send> SessionPool<E> {
    /// Build a pool of `n` sessions from a factory; every session after
    /// the first is re-wired onto the first one's knowledge base.
    pub fn build<F: FnMut(usize) -> Session<E>>(n: usize, mut mk: F) -> SessionPool<E> {
        let mut sessions: Vec<Session<E>> = Vec::with_capacity(n.max(1));
        let mut shared: Option<Arc<RwLock<KnowledgeBase>>> = None;
        for i in 0..n.max(1) {
            let s = mk(i);
            let s = match &shared {
                None => {
                    shared = Some(s.shared_kb());
                    s
                }
                Some(kb) => s.with_shared_kb(kb.clone()),
            };
            sessions.push(s);
        }
        SessionPool { sessions }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn sessions(&self) -> &[Session<E>] {
        &self.sessions
    }

    /// The pool's shared knowledge base handle.
    pub fn shared_kb(&self) -> Arc<RwLock<KnowledgeBase>> {
        self.sessions[0].shared_kb()
    }

    /// Session counters summed over the pool (lifetime totals).
    fn summed_stats(&self) -> SessionStats {
        let mut stats = SessionStats::default();
        for s in &self.sessions {
            let st = s.stats();
            stats.runs += st.runs;
            stats.kb_hits += st.kb_hits;
            stats.derived += st.derived;
            stats.built += st.built;
            stats.pinned += st.pinned;
            stats.balance_ops += st.balance_ops;
            stats.unbalanced_runs += st.unbalanced_runs;
            stats.bytes_uploaded += st.bytes_uploaded;
            stats.bytes_downloaded += st.bytes_downloaded;
            stats.uploads_avoided += st.uploads_avoided;
            stats.steal_migrations += st.steal_migrations;
            stats.idle_frac_sum += st.idle_frac_sum;
        }
        stats
    }

    /// Drain a request stream: up to `opts.concurrency` workers (bounded by
    /// the pool size) pull requests in order. The first error cancels the
    /// remaining stream and is returned.
    pub fn serve(&self, requests: &[ServeRequest], opts: &ServeOpts) -> Result<ServeReport> {
        let workers = opts.concurrency.clamp(1, self.sessions.len());
        if let Some(n) = opts.tasks_per_slot {
            for s in &self.sessions {
                s.set_tasks_per_slot(n);
            }
        }
        if let Some(mode) = opts.drain_mode {
            for s in &self.sessions {
                s.set_drain_mode(mode);
            }
        }
        // Snapshot so the report's stats cover this run only, even when the
        // pool is reused across serve calls.
        let stats_before = self.summed_stats();
        let next = AtomicUsize::new(0);
        let traces: Mutex<Vec<RequestTrace>> = Mutex::new(Vec::with_capacity(requests.len()));
        let failure: Mutex<Option<crate::error::Error>> = Mutex::new(None);

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for (w, session) in self.sessions.iter().take(workers).enumerate() {
                let next = &next;
                let traces = &traces;
                let failure = &failure;
                let pace = opts.pace;
                scope.spawn(move || loop {
                    if failure.lock().unwrap().is_some() {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests.len() {
                        break;
                    }
                    let req = &requests[i];
                    let admitted = Instant::now();
                    match session.run(&req.comp, &req.args) {
                        Ok(out) => {
                            if pace > 0.0 {
                                std::thread::sleep(Duration::from_secs_f64(pace));
                            }
                            traces.lock().unwrap().push(RequestTrace {
                                index: i,
                                worker: w,
                                latency: admitted.elapsed().as_secs_f64(),
                                origin: out.origin,
                                exec_total: out.exec.total,
                            });
                        }
                        Err(e) => {
                            let mut f = failure.lock().unwrap();
                            if f.is_none() {
                                *f = Some(e);
                            }
                            break;
                        }
                    }
                });
            }
        });
        let wall_secs = t0.elapsed().as_secs_f64().max(1e-9);

        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        let mut traces = traces.into_inner().unwrap();
        traces.sort_by_key(|t| t.index);
        let latencies: Vec<f64> = traces.iter().map(|t| t.latency).collect();
        let mean_latency = if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().sum::<f64>() / latencies.len() as f64
        };
        let after = self.summed_stats();
        let stats = SessionStats {
            runs: after.runs - stats_before.runs,
            kb_hits: after.kb_hits - stats_before.kb_hits,
            derived: after.derived - stats_before.derived,
            built: after.built - stats_before.built,
            pinned: after.pinned - stats_before.pinned,
            balance_ops: after.balance_ops - stats_before.balance_ops,
            unbalanced_runs: after.unbalanced_runs - stats_before.unbalanced_runs,
            bytes_uploaded: after.bytes_uploaded - stats_before.bytes_uploaded,
            bytes_downloaded: after.bytes_downloaded - stats_before.bytes_downloaded,
            uploads_avoided: after.uploads_avoided - stats_before.uploads_avoided,
            steal_migrations: after.steal_migrations - stats_before.steal_migrations,
            idle_frac_sum: after.idle_frac_sum - stats_before.idle_frac_sum,
        };
        Ok(ServeReport {
            completed: traces.len(),
            concurrency: workers,
            wall_secs,
            requests_per_sec: traces.len() as f64 / wall_secs,
            p50_latency: percentile(&latencies, 50.0),
            p99_latency: percentile(&latencies, 99.0),
            mean_latency,
            stats,
            traces,
        })
    }
}

/// Serve a request stream over a pool of simulated sessions for `machine`
/// (one per admitted request), sharing one knowledge base.
pub fn serve_simulated(
    machine: &Machine,
    seed: u64,
    requests: &[ServeRequest],
    opts: &ServeOpts,
) -> Result<ServeReport> {
    let pool = SessionPool::build(opts.concurrency.max(1), |i| {
        Session::simulated(machine.clone(), seed + i as u64)
    });
    pool.serve(requests, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::platform::device::i7_hd7950;

    fn requests(n: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|_| ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))))
            .collect()
    }

    #[test]
    fn pool_shares_one_kb_across_sessions() {
        let pool = SessionPool::build(3, |i| Session::simulated(i7_hd7950(1), 40 + i as u64));
        let reqs = requests(6);
        let report = pool
            .serve(&reqs, &ServeOpts { concurrency: 3, pace: 0.0, tasks_per_slot: None, drain_mode: None })
            .unwrap();
        assert_eq!(report.completed, 6);
        // One cold start warms the whole pool: exactly one build (plus any
        // same-instant racers), and the shared KB holds one profile.
        assert_eq!(pool.shared_kb().read().unwrap().len(), 1);
        assert!(report.stats.kb_hits + report.stats.derived >= 3);
    }

    #[test]
    fn serve_reports_latency_percentiles() {
        let reqs = requests(8);
        let report = serve_simulated(
            &i7_hd7950(1),
            7,
            &reqs,
            &ServeOpts { concurrency: 2, pace: 0.002, tasks_per_slot: None, drain_mode: None },
        )
        .unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.requests_per_sec > 0.0);
        assert!(report.p50_latency >= 0.002);
        assert!(report.p99_latency >= report.p50_latency);
        // Every request is accounted for exactly once, in stream order.
        let idx: Vec<usize> = report.traces.iter().map(|t| t.index).collect();
        assert_eq!(idx, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn concurrency_is_capped_by_pool_size() {
        let pool = SessionPool::build(2, |i| Session::simulated(i7_hd7950(1), i as u64));
        let report = pool
            .serve(&requests(4), &ServeOpts { concurrency: 16, pace: 0.0, tasks_per_slot: None, drain_mode: None })
            .unwrap();
        assert_eq!(report.concurrency, 2);
        assert_eq!(report.completed, 4);
    }
}
