//! The unified execution-configuration profile (DESIGN.md §2.13).
//!
//! Sessions historically grew one `with_*`/`set_*` pair per runtime knob
//! (steal slack, prefetch depth, drain mode, residency toggle, balance
//! threshold), and the serve path mirrored each as an `Option` field on
//! `ServeOpts` — three places to touch per knob, and no way to record
//! "the configuration this run executed under" as one value. An
//! [`ExecProfile`] is that value: every field is an `Option`, `None`
//! meaning "keep the backend default", so profiles compose by
//! [`ExecProfile::merge`] and serialize sparsely (only the knobs a run
//! actually pinned). [`Session::apply_exec`] applies one to a live
//! session; [`ServeOpts::exec`] applies one to every pooled session; a
//! recorded replay trace carries the profile its run executed under, so
//! `marrow serve --replay` reproduces the exact configuration.
//!
//! The legacy setters survive as thin delegates routing through
//! [`Session::apply_exec`] — call sites keep compiling, but new code
//! should build an `ExecProfile` once and hand it over.
//!
//! [`Session::apply_exec`]: crate::session::Session::apply_exec
//! [`ServeOpts::exec`]: crate::session::ServeOpts::exec

use crate::cli::Args;
use crate::error::{Error, Result};
use crate::scheduler::DrainMode;
use crate::util::json::Json;

/// Balance threshold `maxDev` the monitor falls back to when a profile
/// leaves [`ExecProfile::max_dev`] unset (the paper's Section 3.3 default).
pub const DEFAULT_MAX_DEV: f64 = 0.85;

/// One session's pinnable runtime knobs. `None` everywhere (the
/// [`Default`]) changes nothing — applying it is a no-op.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExecProfile {
    /// Stealable tasks generated per execution slot (steal slack;
    /// backend default 4). CLI: `--tasks-per-slot`.
    pub tasks_per_slot: Option<u32>,
    /// Prefetch lookahead depth for the dataflow drain (DESIGN.md §2.12;
    /// backend default 0 = no prefetch). CLI: `--prefetch-depth`.
    pub prefetch_depth: Option<u32>,
    /// Drain mode (backend default [`DrainMode::Dataflow`]; `Barrier` is
    /// the A/B baseline). CLI: `--drain`.
    pub drain_mode: Option<DrainMode>,
    /// Buffer-residency layer toggle (backend default on; off is the A/B
    /// baseline for the locality benches). CLI: `--no-residency`.
    pub residency: Option<bool>,
    /// Balance threshold `maxDev` for the execution monitor
    /// ([`DEFAULT_MAX_DEV`] when unset). CLI: `--max-dev`.
    pub max_dev: Option<f64>,
}

impl ExecProfile {
    pub fn new() -> ExecProfile {
        ExecProfile::default()
    }

    pub fn tasks_per_slot(mut self, n: u32) -> ExecProfile {
        self.tasks_per_slot = Some(n);
        self
    }

    pub fn prefetch_depth(mut self, k: u32) -> ExecProfile {
        self.prefetch_depth = Some(k);
        self
    }

    pub fn drain_mode(mut self, mode: DrainMode) -> ExecProfile {
        self.drain_mode = Some(mode);
        self
    }

    pub fn residency(mut self, on: bool) -> ExecProfile {
        self.residency = Some(on);
        self
    }

    pub fn max_dev(mut self, max_dev: f64) -> ExecProfile {
        self.max_dev = Some(max_dev);
        self
    }

    /// Whether every knob is left at the backend default (applying such a
    /// profile changes nothing).
    pub fn is_empty(&self) -> bool {
        *self == ExecProfile::default()
    }

    /// Overlay `other`: its pinned knobs win, unset ones keep ours. The
    /// session's stored profile accumulates setter calls through this.
    pub fn merge(&mut self, other: &ExecProfile) {
        if other.tasks_per_slot.is_some() {
            self.tasks_per_slot = other.tasks_per_slot;
        }
        if other.prefetch_depth.is_some() {
            self.prefetch_depth = other.prefetch_depth;
        }
        if other.drain_mode.is_some() {
            self.drain_mode = other.drain_mode;
        }
        if other.residency.is_some() {
            self.residency = other.residency;
        }
        if other.max_dev.is_some() {
            self.max_dev = other.max_dev;
        }
    }

    /// The effective balance threshold (Section 3.3).
    pub fn max_dev_or_default(&self) -> f64 {
        self.max_dev.unwrap_or(DEFAULT_MAX_DEV)
    }

    /// Sparse JSON: only pinned knobs are emitted, so an empty profile is
    /// `{}` and round-trips to itself.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(n) = self.tasks_per_slot {
            fields.push(("tasks_per_slot", Json::num(n as f64)));
        }
        if let Some(k) = self.prefetch_depth {
            fields.push(("prefetch_depth", Json::num(k as f64)));
        }
        if let Some(mode) = self.drain_mode {
            fields.push(("drain_mode", Json::str(mode.label())));
        }
        if let Some(on) = self.residency {
            fields.push(("residency", Json::Bool(on)));
        }
        if let Some(d) = self.max_dev {
            fields.push(("max_dev", Json::num(d)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<ExecProfile> {
        let mut p = ExecProfile::default();
        p.tasks_per_slot = v
            .get("tasks_per_slot")
            .ok()
            .and_then(|x| x.as_u64())
            .map(|n| n as u32);
        p.prefetch_depth = v
            .get("prefetch_depth")
            .ok()
            .and_then(|x| x.as_u64())
            .map(|k| k as u32);
        if let Ok(mode) = v.get("drain_mode") {
            let s = mode
                .as_str()
                .ok_or_else(|| Error::Kb("drain_mode must be a string".into()))?;
            p.drain_mode = Some(DrainMode::parse(s).ok_or_else(|| {
                Error::Kb(format!("unknown drain_mode '{s}' in exec profile"))
            })?);
        }
        p.residency = v.get("residency").ok().and_then(|x| x.as_bool());
        p.max_dev = v.get("max_dev").ok().and_then(|x| x.as_f64());
        Ok(p)
    }

    /// Parse the CLI's execution knobs once (`--tasks-per-slot`,
    /// `--prefetch-depth`, `--drain`, `--no-residency`, `--max-dev`) —
    /// `run`, `serve`, and `graph` all resolve their flags through here.
    pub fn from_args(args: &Args) -> Result<ExecProfile> {
        let mut p = ExecProfile::default();
        if args.get("tasks-per-slot").is_some() {
            p.tasks_per_slot = Some(args.get_u64("tasks-per-slot", 4)?.max(1) as u32);
        }
        if args.get("prefetch-depth").is_some() {
            p.prefetch_depth = Some(args.get_u64("prefetch-depth", 0)? as u32);
        }
        if let Some(s) = args.get("drain") {
            p.drain_mode = Some(DrainMode::parse(s).ok_or_else(|| {
                Error::Usage(format!(
                    "--drain expects 'barrier' or 'dataflow', got '{s}'"
                ))
            })?);
        }
        if args.has("no-residency") {
            p.residency = Some(false);
        }
        if args.get("max-dev").is_some() {
            p.max_dev = Some(args.get_f64("max-dev", DEFAULT_MAX_DEV)?);
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_merge_and_empty() {
        assert!(ExecProfile::new().is_empty());
        let a = ExecProfile::new().tasks_per_slot(8).max_dev(0.7);
        let b = ExecProfile::new()
            .tasks_per_slot(2)
            .drain_mode(DrainMode::Barrier);
        let mut merged = a.clone();
        merged.merge(&b);
        // b's pinned knobs win; a's unset-in-b knobs survive.
        assert_eq!(merged.tasks_per_slot, Some(2));
        assert_eq!(merged.drain_mode, Some(DrainMode::Barrier));
        assert_eq!(merged.max_dev, Some(0.7));
        assert!(!merged.is_empty());
        assert_eq!(ExecProfile::new().max_dev_or_default(), DEFAULT_MAX_DEV);
    }

    #[test]
    fn json_round_trip_is_sparse() {
        assert_eq!(ExecProfile::new().to_json().to_string(), "{}");
        let p = ExecProfile::new()
            .tasks_per_slot(8)
            .prefetch_depth(3)
            .drain_mode(DrainMode::Barrier)
            .residency(false)
            .max_dev(0.9);
        let back = ExecProfile::from_json(&Json::parse(&p.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, p);
        // Unknown drain labels are a clean parse error, not a silent skip.
        let bad = Json::parse("{\"drain_mode\": \"eager\"}").unwrap();
        assert!(ExecProfile::from_json(&bad).is_err());
    }

    #[test]
    fn cli_flags_parse_once() {
        let args = Args::parse(
            "serve --tasks-per-slot 8 --drain barrier --prefetch-depth 2 \
             --no-residency --max-dev 0.7"
                .split_whitespace()
                .map(String::from),
        );
        let p = ExecProfile::from_args(&args).unwrap();
        assert_eq!(p.tasks_per_slot, Some(8));
        assert_eq!(p.drain_mode, Some(DrainMode::Barrier));
        assert_eq!(p.prefetch_depth, Some(2));
        assert_eq!(p.residency, Some(false));
        assert_eq!(p.max_dev, Some(0.7));
        // Absent flags stay None — the backend defaults rule.
        let empty = ExecProfile::from_args(&Args::default()).unwrap();
        assert!(empty.is_empty());
        let bad = Args::parse(
            "serve --drain sideways".split_whitespace().map(String::from),
        );
        assert!(ExecProfile::from_args(&bad).is_err());
    }
}
