//! Scattered-data interpolation for configuration derivation
//! (Section 3.2.3): Gaussian RBF network (dims 1-3, the paper uses Alglib's
//! Fast RBF) and nearest-neighbour with inverse-distance weighting (dims > 3).

use crate::error::Result;
use crate::util::linalg::{dist, solve_general, solve_spd, Mat};

/// Fit + evaluate a Gaussian RBF network at `target`.
///
/// phi(r) = exp(-(r/sigma)^2) with sigma the median pairwise distance;
/// weights solve (Phi + lambda I) w = y. Returns `None`-ish error only for
/// degenerate systems — callers fall back to nearest-neighbour.
pub fn rbf_interpolate(points: &[Vec<f64>], values: &[f64], target: &[f64]) -> Option<f64> {
    let n = points.len();
    if n == 0 {
        return None;
    }
    if n == 1 {
        return Some(values[0]);
    }
    // Bandwidth: median pairwise distance.
    let mut dists = Vec::new();
    for i in 0..n {
        for j in i + 1..n {
            dists.push(dist(&points[i], &points[j]));
        }
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sigma = dists[dists.len() / 2].max(1e-9);

    let phi = |r: f64| (-(r / sigma) * (r / sigma)).exp();
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = phi(dist(&points[i], &points[j]));
            a.set(i, j, v + if i == j { 1e-8 } else { 0.0 });
        }
    }
    let w = match solve_spd(&a, values) {
        Ok(w) => w,
        Err(_) => solve_general(&a, values).ok()?,
    };
    let mut y = 0.0;
    for (p, wi) in points.iter().zip(&w) {
        y += wi * phi(dist(p, target));
    }
    Some(y)
}

/// Inverse-distance-weighted nearest neighbours (Euclidean metric) — the
/// derivation method for work spaces of dimension > 3.
pub fn nearest_neighbour(points: &[Vec<f64>], values: &[f64], target: &[f64]) -> Option<f64> {
    if points.is_empty() {
        return None;
    }
    // Exact hit?
    for (p, v) in points.iter().zip(values) {
        if dist(p, target) < 1e-12 {
            return Some(*v);
        }
    }
    // k=3 inverse-distance weighting.
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        dist(&points[a], target)
            .partial_cmp(&dist(&points[b], target))
            .unwrap()
    });
    let k = idx.len().min(3);
    let (mut num, mut den) = (0.0, 0.0);
    for &i in &idx[..k] {
        let w = 1.0 / dist(&points[i], target).max(1e-12);
        num += w * values[i];
        den += w;
    }
    Some(num / den)
}

/// Interpolation helper honouring the paper's dimensionality rule.
pub fn interpolate(
    points: &[Vec<f64>],
    values: &[f64],
    target: &[f64],
) -> Result<f64> {
    let v = if target.len() <= 3 {
        rbf_interpolate(points, values, target)
            .or_else(|| nearest_neighbour(points, values, target))
    } else {
        nearest_neighbour(points, values, target)
    };
    v.ok_or_else(|| crate::Error::Kb("no data to interpolate".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_reproduces_training_points() {
        let pts = vec![vec![0.0], vec![1.0], vec![2.0], vec![3.0]];
        let vals = vec![0.0, 1.0, 4.0, 9.0];
        for (p, v) in pts.iter().zip(&vals) {
            let y = rbf_interpolate(&pts, &vals, p).unwrap();
            assert!((y - v).abs() < 1e-3, "at {p:?}: {y} vs {v}");
        }
    }

    #[test]
    fn rbf_interpolates_smoothly_between_points() {
        let pts = vec![vec![0.0], vec![2.0]];
        let vals = vec![0.0, 1.0];
        let mid = rbf_interpolate(&pts, &vals, &[1.0]).unwrap();
        assert!(mid > 0.2 && mid < 0.8, "mid {mid}");
    }

    #[test]
    fn rbf_2d() {
        let pts = vec![
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
        ];
        let vals = vec![0.0, 1.0, 1.0, 2.0]; // f = x + y
        let c = rbf_interpolate(&pts, &vals, &[0.5, 0.5]).unwrap();
        // Gaussian RBF overshoots between training points; the derivation
        // clamps shares to [0,1], so a loose band is the right contract.
        assert!((c - 1.0).abs() < 0.5, "centre {c}");
    }

    #[test]
    fn nn_exact_hit() {
        let pts = vec![vec![1.0, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0, 8.0]];
        let vals = vec![10.0, 20.0];
        assert_eq!(
            nearest_neighbour(&pts, &vals, &[1.0, 2.0, 3.0, 4.0]).unwrap(),
            10.0
        );
    }

    #[test]
    fn nn_weights_by_distance() {
        let pts = vec![vec![0.0], vec![10.0]];
        let vals = vec![0.0, 1.0];
        let y = nearest_neighbour(&pts, &vals, &[1.0]).unwrap();
        assert!(y < 0.5, "near the 0-point: {y}");
    }

    #[test]
    fn single_point_constant() {
        let pts = vec![vec![5.0]];
        let vals = vec![0.7];
        assert_eq!(rbf_interpolate(&pts, &vals, &[100.0]).unwrap(), 0.7);
        assert_eq!(nearest_neighbour(&pts, &vals, &[100.0]).unwrap(), 0.7);
    }

    #[test]
    fn dispatch_by_dimensionality() {
        let pts4 = vec![vec![0.0; 4], vec![1.0; 4]];
        let vals = vec![0.0, 1.0];
        assert!(interpolate(&pts4, &vals, &[0.1; 4]).unwrap() < 0.5);
        let pts1 = vec![vec![0.0], vec![1.0]];
        assert!(interpolate(&pts1, &vals, &[0.9]).unwrap() > 0.5);
    }
}
