//! Snapshot export/import (DESIGN.md §2.9): a self-describing bundle of
//! store records fleet nodes exchange. Encoding is canonical — records
//! sorted by content key, store-local state (epochs, segment layout)
//! excluded — so two stores holding the same merged record set export
//! byte-identical snapshots regardless of the order records arrived in.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::error::{Error, Result};
use crate::kb::store::{fold_record, KbStore, StoreRecord, STORE_FORMAT};
use crate::util::fsio::atomic_write;
use crate::util::json::Json;

/// A portable, canonical bundle of store records.
#[derive(Clone, Debug, Default)]
pub struct KbSnapshot {
    /// Records keyed by content key — iteration order is the canonical
    /// serialization order.
    records: BTreeMap<String, StoreRecord>,
}

impl KbSnapshot {
    /// Snapshot of a store's full merged view (staged records included).
    pub fn from_store(store: &KbStore) -> KbSnapshot {
        KbSnapshot::from_records(store.records().cloned())
    }

    /// Snapshot of arbitrary records, merged under the store's total
    /// order if keys collide.
    pub fn from_records(records: impl IntoIterator<Item = StoreRecord>) -> KbSnapshot {
        let mut map = BTreeMap::new();
        for rec in records {
            fold_record(&mut map, rec);
        }
        KbSnapshot { records: map }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> impl Iterator<Item = &StoreRecord> {
        self.records.values()
    }

    /// Distinct machine manifest digests covered, sorted.
    pub fn manifest_digests(&self) -> Vec<String> {
        let set: BTreeSet<String> = self
            .records
            .values()
            .map(|r| r.manifest_digest.clone())
            .collect();
        set.into_iter().collect()
    }

    /// Canonical bytes: equal record sets encode identically.
    pub fn encode(&self) -> String {
        let v = Json::obj(vec![
            ("format", Json::str(STORE_FORMAT)),
            ("kind", Json::str("snapshot")),
            (
                "manifest_digests",
                Json::arr(
                    self.manifest_digests()
                        .iter()
                        .map(|d| Json::str(d.as_str()))
                        .collect(),
                ),
            ),
            ("record_count", Json::num(self.records.len() as f64)),
            (
                "records",
                Json::arr(self.records.values().map(|r| r.to_json()).collect()),
            ),
        ]);
        v.to_string_pretty()
    }

    pub fn parse(text: &str) -> Result<KbSnapshot> {
        let v = Json::parse(text)?;
        if v.get("kind").ok().and_then(|k| k.as_str()) != Some("snapshot") {
            return Err(Error::Kb(
                "not a kb snapshot (missing kind: \"snapshot\")".into(),
            ));
        }
        let mut map = BTreeMap::new();
        for r in v.get("records")?.as_arr().unwrap_or(&[]) {
            fold_record(&mut map, StoreRecord::from_json(r)?);
        }
        Ok(KbSnapshot { records: map })
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        atomic_write(path, self.encode().as_bytes())
    }

    pub fn read(path: &Path) -> Result<KbSnapshot> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Kb(format!("{}: {e}", path.display())))?;
        KbSnapshot::parse(&text)
    }

    /// Merge this snapshot's records into `store` (staged, not yet
    /// flushed). Idempotent and commutative: see
    /// [`replaces`](crate::kb::store::replaces). Returns how many
    /// records changed the store's merged view.
    pub fn merge_into(&self, store: &mut KbStore) -> usize {
        self.records
            .values()
            .filter(|rec| store.stage_record((*rec).clone()))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workload::Workload;
    use crate::kb::mk_profile;
    use crate::platform::cpu::FissionLevel;

    fn rec(sct: &str, n: u64, time: f64, digest: &str) -> StoreRecord {
        StoreRecord::new(
            mk_profile(sct, Workload::d1(n), FissionLevel::L2, vec![4], 0.2, time),
            digest,
        )
    }

    #[test]
    fn encode_parse_roundtrip_is_canonical() {
        let snap = KbSnapshot::from_records(vec![
            rec("b", 2048, 1.0, "m0"),
            rec("a", 1024, 2.0, "m1"),
        ]);
        let text = snap.encode();
        let back = KbSnapshot::parse(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.encode(), text);
        // Insertion order does not change the bytes.
        let flipped = KbSnapshot::from_records(vec![
            rec("a", 1024, 2.0, "m1"),
            rec("b", 2048, 1.0, "m0"),
        ]);
        assert_eq!(flipped.encode(), text);
        assert_eq!(snap.manifest_digests(), vec!["m0".to_string(), "m1".to_string()]);
    }

    #[test]
    fn colliding_keys_keep_best() {
        let snap = KbSnapshot::from_records(vec![
            rec("a", 1024, 2.0, "m0"),
            rec("a", 1024, 1.0, "m0"),
        ]);
        assert_eq!(snap.len(), 1);
        assert_eq!(snap.records().next().unwrap().profile.best_time, 1.0);
    }

    #[test]
    fn rejects_non_snapshot_json() {
        assert!(KbSnapshot::parse("{\"profiles\": []}").is_err());
        assert!(KbSnapshot::parse("not json at all").is_err());
    }
}
