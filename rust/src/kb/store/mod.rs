//! Durable content-addressed profile store (DESIGN.md §2.9): the
//! persistence layer beneath [`KnowledgeBase`](crate::kb::KnowledgeBase).
//!
//! Profiles are immutable [`StoreRecord`]s keyed by a SHA-256 content key
//! over (SCT id, workload id, machine manifest digest). On disk a store
//! is a directory of append-only *segment* files — each an atomic
//! write-temp + fsync + rename commit of one flush's records — plus a
//! `meta.json` index carrying the monotonic store epoch. The directory
//! scan is authoritative on open/reload; `meta.json` is a hint, so two
//! processes flushing uniquely-named segments into the same directory
//! interleave without losing records.
//!
//! Replay in any order converges to the same state because records merge
//! under a *total* order ([`replaces`]): smaller `best_time` wins, then
//! `Refined` origin, then the lexicographically smaller canonical
//! encoding — which is what makes snapshot merge idempotent, commutative
//! and associative across fleet nodes.

pub mod snapshot;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::platform::device::Machine;
use crate::tuner::profile::{Profile, ProfileOrigin};
use crate::util::fsio::atomic_write;
use crate::util::hash::sha256_hex;
use crate::util::json::Json;

/// Format tag of every segment / meta / snapshot file this code writes.
pub const STORE_FORMAT: &str = "marrow-kb-store-v1";

/// Content key of a profile: the store address of the best-known
/// configuration for one (SCT, workload) pair *on one machine manifest*.
pub fn content_key(sct_id: &str, workload_id: &str, manifest_digest: &str) -> String {
    sha256_hex(
        format!("marrow-profile-v1\0{sct_id}\0{workload_id}\0{manifest_digest}")
            .as_bytes(),
    )
}

/// Digest of a machine manifest under a backend kind tag ("analytic" for
/// simulated/model-driven backends, "real" for OpenCL/PJRT schedulers,
/// which also fold in their kernel-artifact manifest). Profiles are
/// exchangeable as exact warm-start hits only between equal digests.
pub fn machine_digest(kind: &str, machine: &Machine) -> String {
    sha256_hex(format!("{kind}\0{}", machine.manifest_json().to_string()).as_bytes())
}

/// One immutable stored profile: the unit of persistence, snapshot
/// exchange and merge.
#[derive(Clone, Debug)]
pub struct StoreRecord {
    /// Content key — [`content_key`] of the fields below.
    pub key: String,
    /// Digest of the machine manifest the profile was measured on.
    pub manifest_digest: String,
    pub profile: Profile,
}

impl StoreRecord {
    pub fn new(profile: Profile, manifest_digest: &str) -> StoreRecord {
        StoreRecord {
            key: content_key(&profile.sct_id, &profile.workload.id(), manifest_digest),
            manifest_digest: manifest_digest.to_string(),
            profile,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(self.key.as_str())),
            ("manifest_digest", Json::str(self.manifest_digest.as_str())),
            ("profile", self.profile.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<StoreRecord> {
        let profile = Profile::from_json(v.get("profile")?)?;
        let manifest_digest = v
            .get("manifest_digest")?
            .as_str()
            .unwrap_or("")
            .to_string();
        let key = v.get("key")?.as_str().unwrap_or("").to_string();
        let expect = content_key(&profile.sct_id, &profile.workload.id(), &manifest_digest);
        if key != expect {
            return Err(Error::Kb(format!(
                "store record key mismatch: {key} != {expect} (corrupt record?)"
            )));
        }
        Ok(StoreRecord {
            key,
            manifest_digest,
            profile,
        })
    }

    /// Canonical single-line encoding — the merge tiebreaker and the byte
    /// content snapshots serialize, so equal records encode equally.
    pub fn canonical(&self) -> String {
        self.to_json().to_string()
    }
}

fn origin_rank(o: ProfileOrigin) -> u8 {
    match o {
        ProfileOrigin::Refined => 2,
        ProfileOrigin::Built => 1,
        ProfileOrigin::Derived => 0,
    }
}

/// Total order deciding whether `incoming` replaces `current` for the
/// same content key: strictly better (smaller) `best_time` wins; on equal
/// times the higher-ranked origin (`Refined` > `Built` > `Derived`) wins;
/// a residual tie falls to the lexicographically smaller canonical
/// encoding. Totality (no "keep whichever arrived first" case) is what
/// makes merge order-independent. NaN times always lose.
pub fn replaces(incoming: &StoreRecord, current: &StoreRecord) -> bool {
    match incoming.profile.best_time.total_cmp(&current.profile.best_time) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => {
            let (ri, rc) = (
                origin_rank(incoming.profile.origin),
                origin_rank(current.profile.origin),
            );
            if ri != rc {
                ri > rc
            } else {
                incoming.canonical() < current.canonical()
            }
        }
    }
}

/// Merge `rec` into a key-indexed record map under the [`replaces`]
/// order. Returns whether the map changed (new key or replacement).
pub fn fold_record(map: &mut BTreeMap<String, StoreRecord>, rec: StoreRecord) -> bool {
    match map.get(&rec.key) {
        Some(current) if !replaces(&rec, current) => false,
        _ => {
            map.insert(rec.key.clone(), rec);
            true
        }
    }
}

/// Distinguishes segment files flushed by this process within one epoch.
static SEG_NONCE: AtomicU64 = AtomicU64::new(0);

/// Aggregate counters for `marrow kb stats`.
#[derive(Debug, Default)]
pub struct StoreStats {
    pub records: usize,
    pub segments: usize,
    pub epoch: u64,
    /// Records per machine manifest digest.
    pub digests: BTreeMap<String, usize>,
    /// Records per profile origin label.
    pub origins: BTreeMap<&'static str, usize>,
}

/// An open store: the in-memory merged view of every segment read so
/// far, plus records staged for the next flush.
#[derive(Debug)]
pub struct KbStore {
    dir: PathBuf,
    /// Local machine manifest digest — the default digest for staged
    /// profiles and the "exact hit" side of warm-start compatibility.
    manifest_digest: String,
    records: BTreeMap<String, StoreRecord>,
    /// Segment file names already folded into `records`.
    loaded_segments: BTreeSet<String>,
    /// Monotonic store epoch: bumped by every flush in any process.
    epoch: u64,
    /// Records staged by [`stage`](KbStore::stage) since the last flush.
    pending: BTreeMap<String, StoreRecord>,
}

impl KbStore {
    /// Open (creating if needed) the store directory and fold in every
    /// segment present. A corrupt segment is an error, not an empty
    /// store.
    pub fn open(dir: &Path, manifest_digest: &str) -> Result<KbStore> {
        std::fs::create_dir_all(dir)?;
        let mut store = KbStore {
            dir: dir.to_path_buf(),
            manifest_digest: manifest_digest.to_string(),
            records: BTreeMap::new(),
            loaded_segments: BTreeSet::new(),
            epoch: 0,
            pending: BTreeMap::new(),
        };
        store.epoch = store.disk_epoch()?;
        store.reload()?;
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest_digest(&self) -> &str {
        &self.manifest_digest
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Merged view of every record, keyed and iterated in key order.
    pub fn records(&self) -> impl Iterator<Item = &StoreRecord> {
        self.records.values()
    }

    pub fn get(&self, key: &str) -> Option<&StoreRecord> {
        self.records.get(key)
    }

    /// Stage a profile under `digest` (default: the store's local
    /// digest). Applied to the merged view immediately; persisted by the
    /// next [`flush`](KbStore::flush). Returns whether the merged view
    /// improved.
    pub fn stage(&mut self, profile: Profile, digest: Option<&str>) -> bool {
        let digest = digest.unwrap_or(&self.manifest_digest).to_string();
        self.stage_record(StoreRecord::new(profile, &digest))
    }

    /// Stage a pre-keyed record (snapshot import path).
    pub fn stage_record(&mut self, rec: StoreRecord) -> bool {
        if fold_record(&mut self.records, rec.clone()) {
            self.pending.insert(rec.key.clone(), rec);
            true
        } else {
            false
        }
    }

    /// Commit staged records as one new segment file (atomic), bump the
    /// epoch and rewrite `meta.json`. A no-op with nothing pending.
    /// Returns the number of records committed.
    pub fn flush(&mut self) -> Result<usize> {
        if self.pending.is_empty() {
            return Ok(0);
        }
        // Absorb concurrent flushes first so our epoch strictly advances
        // past everything visible on disk.
        self.reload()?;
        self.epoch = self.epoch.max(self.disk_epoch()?) + 1;
        let recs: Vec<StoreRecord> = self.pending.values().cloned().collect();
        let name = format!(
            "seg-{:010}-{}-{}.json",
            self.epoch,
            std::process::id(),
            SEG_NONCE.fetch_add(1, Ordering::Relaxed)
        );
        let body = Json::obj(vec![
            ("format", Json::str(STORE_FORMAT)),
            ("kind", Json::str("segment")),
            ("epoch", Json::num(self.epoch as f64)),
            (
                "records",
                Json::arr(recs.iter().map(|r| r.to_json()).collect()),
            ),
        ]);
        atomic_write(&self.dir.join(&name), body.to_string_pretty().as_bytes())?;
        self.loaded_segments.insert(name);
        self.write_meta()?;
        self.pending.clear();
        Ok(recs.len())
    }

    fn write_meta(&self) -> Result<()> {
        let meta = Json::obj(vec![
            ("format", Json::str(STORE_FORMAT)),
            ("kind", Json::str("meta")),
            ("epoch", Json::num(self.epoch as f64)),
            ("segments", Json::num(self.loaded_segments.len() as f64)),
            (
                "manifest_digest",
                Json::str(self.manifest_digest.as_str()),
            ),
        ]);
        atomic_write(&self.dir.join("meta.json"), meta.to_string_pretty().as_bytes())
    }

    /// The newest epoch visible on disk: the max of `meta.json`'s epoch
    /// (a hint — it can lag concurrent writers) and the segment names
    /// (authoritative).
    pub fn disk_epoch(&self) -> Result<u64> {
        let mut epoch = 0u64;
        let meta_path = self.dir.join("meta.json");
        if meta_path.exists() {
            let text = std::fs::read_to_string(&meta_path)?;
            if let Ok(v) = Json::parse(&text) {
                if let Some(e) = v.get("epoch").ok().and_then(|e| e.as_u64()) {
                    epoch = e;
                }
            }
        }
        for name in self.segment_files()? {
            if let Some(e) = segment_epoch(&name) {
                epoch = epoch.max(e);
            }
        }
        Ok(epoch)
    }

    /// Does the directory hold segments this store has not folded in —
    /// i.e. has another process flushed since our last reload?
    pub fn stale(&self) -> Result<bool> {
        Ok(self
            .segment_files()?
            .iter()
            .any(|n| !self.loaded_segments.contains(n)))
    }

    /// Fold in every segment not yet loaded. Order-independent: records
    /// merge under the [`replaces`] total order. Returns the number of
    /// records that changed the merged view.
    pub fn reload(&mut self) -> Result<usize> {
        let mut absorbed = 0;
        for name in self.segment_files()? {
            if self.loaded_segments.contains(&name) {
                continue;
            }
            for rec in read_segment(&self.dir.join(&name))? {
                if fold_record(&mut self.records, rec) {
                    absorbed += 1;
                }
            }
            if let Some(e) = segment_epoch(&name) {
                self.epoch = self.epoch.max(e);
            }
            self.loaded_segments.insert(name);
        }
        Ok(absorbed)
    }

    /// Compact every live record into a single fresh segment, delete the
    /// superseded segments and sweep orphaned `.tmp-` files. Returns
    /// (live records, segments removed).
    pub fn gc(&mut self) -> Result<(usize, usize)> {
        self.reload()?;
        let old: Vec<String> = self.segment_files()?;
        self.epoch = self.epoch.max(self.disk_epoch()?) + 1;
        let name = format!(
            "seg-{:010}-{}-{}.json",
            self.epoch,
            std::process::id(),
            SEG_NONCE.fetch_add(1, Ordering::Relaxed)
        );
        let body = Json::obj(vec![
            ("format", Json::str(STORE_FORMAT)),
            ("kind", Json::str("segment")),
            ("epoch", Json::num(self.epoch as f64)),
            (
                "records",
                Json::arr(self.records.values().map(|r| r.to_json()).collect()),
            ),
        ]);
        atomic_write(&self.dir.join(&name), body.to_string_pretty().as_bytes())?;
        let mut removed = 0;
        for stale in &old {
            if *stale != name && std::fs::remove_file(self.dir.join(stale)).is_ok() {
                removed += 1;
            }
        }
        for entry in std::fs::read_dir(&self.dir)?.filter_map(|e| e.ok()) {
            let n = entry.file_name().to_string_lossy().into_owned();
            if n.starts_with(".tmp-") {
                let _ = std::fs::remove_file(entry.path());
            }
        }
        self.loaded_segments = BTreeSet::new();
        self.loaded_segments.insert(name);
        self.pending.clear();
        self.write_meta()?;
        Ok((self.records.len(), removed))
    }

    pub fn stats(&self) -> StoreStats {
        let mut st = StoreStats {
            records: self.records.len(),
            segments: self.loaded_segments.len(),
            epoch: self.epoch,
            ..StoreStats::default()
        };
        for r in self.records.values() {
            *st.digests.entry(r.manifest_digest.clone()).or_insert(0) += 1;
            *st.origins.entry(r.profile.origin.label()).or_insert(0) += 1;
        }
        st
    }

    /// Sorted segment file names currently present in the directory.
    fn segment_files(&self) -> Result<Vec<String>> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("seg-") && n.ends_with(".json"))
            .collect();
        names.sort();
        Ok(names)
    }
}

/// Epoch parsed from a `seg-{epoch:010}-{pid}-{nonce}.json` name.
fn segment_epoch(name: &str) -> Option<u64> {
    name.strip_prefix("seg-")?.split('-').next()?.parse().ok()
}

/// Parse one segment file; corrupt contents are an error.
fn read_segment(path: &Path) -> Result<Vec<StoreRecord>> {
    let text = std::fs::read_to_string(path)?;
    let v = Json::parse(&text).map_err(|e| {
        Error::Kb(format!("corrupt kb segment {}: {e:?}", path.display()))
    })?;
    if v.get("kind").ok().and_then(|k| k.as_str()) != Some("segment") {
        return Err(Error::Kb(format!(
            "{}: not a kb store segment",
            path.display()
        )));
    }
    let mut out = Vec::new();
    for r in v.get("records")?.as_arr().unwrap_or(&[]) {
        out.push(StoreRecord::from_json(r)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workload::Workload;
    use crate::kb::mk_profile;
    use crate::platform::cpu::FissionLevel;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("marrow_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn rec(sct: &str, n: u64, time: f64) -> StoreRecord {
        StoreRecord::new(
            mk_profile(sct, Workload::d1(n), FissionLevel::L2, vec![4], 0.2, time),
            "m0",
        )
    }

    #[test]
    fn content_key_is_stable_and_digest_sensitive() {
        let a = content_key("saxpy", "1d:1024:f32", "m0");
        assert_eq!(a, content_key("saxpy", "1d:1024:f32", "m0"));
        assert_ne!(a, content_key("saxpy", "1d:1024:f32", "m1"));
        assert_ne!(a, content_key("saxpy", "1d:2048:f32", "m0"));
        assert_eq!(a.len(), 64);
    }

    #[test]
    fn replaces_is_a_total_order() {
        let fast = rec("f", 1024, 1.0);
        let slow = rec("f", 1024, 2.0);
        assert!(replaces(&fast, &slow));
        assert!(!replaces(&slow, &fast));
        // Equal time: Refined beats Built.
        let mut refined = rec("f", 1024, 1.0);
        refined.profile.origin = ProfileOrigin::Refined;
        assert!(replaces(&refined, &fast));
        assert!(!replaces(&fast, &refined));
        // Full tie: never both directions (antisymmetry).
        assert!(!replaces(&fast, &fast.clone()));
        // NaN always loses.
        let nan = rec("f", 1024, f64::NAN);
        assert!(replaces(&fast, &nan));
        assert!(!replaces(&nan, &fast));
    }

    #[test]
    fn flush_and_reopen_roundtrip() {
        let dir = tmp("roundtrip");
        {
            let mut st = KbStore::open(&dir, "m0").unwrap();
            assert!(st.stage(rec("f", 1024, 2.0).profile, None));
            assert!(st.stage(rec("g", 2048, 1.0).profile, None));
            assert_eq!(st.flush().unwrap(), 2);
            // Better time for f replaces; flush only commits the delta.
            assert!(st.stage(rec("f", 1024, 1.5).profile, None));
            assert_eq!(st.flush().unwrap(), 1);
            assert!(!st.stage(rec("f", 1024, 9.0).profile, None));
        }
        let st = KbStore::open(&dir, "m0").unwrap();
        assert_eq!(st.len(), 2);
        let key = content_key("f", "1d:1024:f32", "m0");
        assert_eq!(st.get(&key).unwrap().profile.best_time, 1.5);
        assert_eq!(st.epoch(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reload_absorbs_foreign_segments() {
        let dir = tmp("reload");
        let mut a = KbStore::open(&dir, "m0").unwrap();
        let mut b = KbStore::open(&dir, "m0").unwrap();
        a.stage(rec("f", 1024, 1.0).profile, None);
        a.flush().unwrap();
        assert!(b.stale().unwrap());
        assert_eq!(b.reload().unwrap(), 1);
        assert_eq!(b.len(), 1);
        assert!(!b.stale().unwrap());
        assert_eq!(b.epoch(), a.epoch());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_compacts_without_losing_records() {
        let dir = tmp("gc");
        let mut st = KbStore::open(&dir, "m0").unwrap();
        for i in 0..3u64 {
            st.stage(rec("f", 1024 << i, 1.0 + i as f64).profile, None);
            st.flush().unwrap();
        }
        assert_eq!(st.stats().segments, 3);
        let (live, removed) = st.gc().unwrap();
        assert_eq!((live, removed), (3, 3));
        assert_eq!(st.stats().segments, 1);
        let reopened = KbStore::open(&dir, "m0").unwrap();
        assert_eq!(reopened.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_segment_is_an_error() {
        let dir = tmp("corrupt");
        let mut st = KbStore::open(&dir, "m0").unwrap();
        st.stage(rec("f", 1024, 1.0).profile, None);
        st.flush().unwrap();
        let seg = st.segment_files().unwrap().remove(0);
        std::fs::write(dir.join(&seg), "{ \"records\": [ trunca").unwrap();
        assert!(KbStore::open(&dir, "m0").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
