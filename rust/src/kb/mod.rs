//! The Knowledge Base (Section 3.2.3): stores the best-known configuration
//! per (SCT, workload) pair, persists durably, and *derives* configurations
//! for unseen pairs via multidimensional interpolation of scattered data —
//! an RBF network for workspaces of dimension 1-3, nearest-neighbour above.
//!
//! Derivation narrows scope progressively: configurations of the same SCT
//! first; failing that, configurations of the same workload regardless of
//! SCT; failing that, any workload of the same dimensionality.
//!
//! Persistence has two backings (DESIGN.md §2.9): the legacy single-file
//! JSON KB (whole-file atomic rewrite on [`save`](KnowledgeBase::save)),
//! and the durable content-addressed [`store`] — append-only segments a
//! `KnowledgeBase` writes through incrementally, with snapshot
//! export/import for fleet exchange. Imported profiles whose machine
//! manifest digest matches the local platform become exact entries
//! (warm-start: no Algorithm 1 cold build); mismatched-digest profiles
//! are demoted to *derivation hints* — they feed
//! [`derive`](KnowledgeBase::derive)'s interpolation scopes but never an
//! exact [`lookup`](KnowledgeBase::lookup).

pub mod interp;
pub mod store;

use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};

use crate::data::workload::{Workload, WorkloadClass};
use crate::error::{Error, Result};
use crate::platform::cpu::FissionLevel;
use crate::tuner::profile::{FrameworkConfig, Profile, ProfileOrigin};
use crate::util::fsio::atomic_write;
use crate::util::json::Json;

use store::snapshot::KbSnapshot;
use store::{KbStore, StoreRecord};

/// `sct|workload` identity of one KB entry (machine-local, unlike the
/// store's digest-qualified content key).
fn pair_key(sct_id: &str, workload_id: &str) -> String {
    format!("{sct_id}|{workload_id}")
}

/// Running per-class cost model (ROADMAP item 4): mean and dispersion of
/// observed seconds-per-element for one [`WorkloadClass`]. Irregular
/// classes (sparse/traversal/divergent) carry data-dependent cost the
/// per-size RBF interpolation cannot see — two sparse matrices of equal
/// shape can differ arbitrarily in work — so the KB accumulates what the
/// class actually costs per element and estimates unseen sizes by
/// rescaling that mean.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassModel {
    /// Observations folded in.
    pub count: u64,
    /// Sum of observed seconds-per-element.
    pub sum: f64,
    /// Sum of squared seconds-per-element (for the dispersion).
    pub sum_sq: f64,
}

impl ClassModel {
    /// Fold one observed run: `secs` over `elems` elements.
    pub fn observe(&mut self, elems: u64, secs: f64) {
        if elems == 0 || !(secs > 0.0) {
            return;
        }
        let spe = secs / elems as f64;
        self.count += 1;
        self.sum += spe;
        self.sum_sq += spe * spe;
    }

    /// Mean seconds-per-element over the observations.
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    /// Coefficient of variation of the observed per-element cost — the
    /// dispersion bound the propcheck suite asserts estimates within.
    pub fn dispersion(&self) -> f64 {
        let Some(m) = self.mean() else { return 0.0 };
        if self.count < 2 || m <= 0.0 {
            return 0.0;
        }
        let var = (self.sum_sq / self.count as f64 - m * m).max(0.0);
        var.sqrt() / m
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
            ("sum_sq", Json::num(self.sum_sq)),
        ])
    }

    fn from_json(v: &Json) -> Result<ClassModel> {
        Ok(ClassModel {
            count: v.get("count")?.as_u64().unwrap_or(0),
            sum: v.get("sum")?.as_f64().unwrap_or(0.0),
            sum_sq: v.get("sum_sq")?.as_f64().unwrap_or(0.0),
        })
    }
}

/// The knowledge base. `Clone` snapshots the current profiles (used when
/// extracting a KB that other sessions still share) — the clone is
/// detached from any durable store backing so two writers never share
/// one store handle.
#[derive(Default)]
pub struct KnowledgeBase {
    entries: Vec<Profile>,
    path: Option<PathBuf>,
    /// Local machine manifest digest, when known (always set for
    /// store-backed KBs): the "exact hit" side of import compatibility.
    manifest_digest: Option<String>,
    /// Foreign-manifest records: derivation hints, never exact hits.
    hints: Vec<StoreRecord>,
    /// Pair keys whose current entry came from the store / a snapshot
    /// rather than a local build — the warm-start provenance marker,
    /// cleared when a local measurement replaces the entry.
    imported: HashSet<String>,
    /// Durable write-through backing, if any.
    kb_store: Option<KbStore>,
    /// Per-class cost models, keyed by [`WorkloadClass::label`] — the
    /// interpolation fallback for irregular classes (machine-local, so
    /// persisted with the legacy JSON but never exchanged via store
    /// records, which carry platform provenance per profile instead).
    class_models: BTreeMap<String, ClassModel>,
}

impl Clone for KnowledgeBase {
    fn clone(&self) -> KnowledgeBase {
        KnowledgeBase {
            entries: self.entries.clone(),
            path: self.path.clone(),
            manifest_digest: self.manifest_digest.clone(),
            hints: self.hints.clone(),
            imported: self.imported.clone(),
            kb_store: None,
            class_models: self.class_models.clone(),
        }
    }
}

impl KnowledgeBase {
    pub fn in_memory() -> KnowledgeBase {
        KnowledgeBase::default()
    }

    /// Open (or create) a legacy JSON-backed KB. A present-but-corrupt
    /// file is an error, never silently an empty KB.
    pub fn open(path: &Path) -> Result<KnowledgeBase> {
        let mut kb = KnowledgeBase {
            path: Some(path.to_path_buf()),
            ..KnowledgeBase::default()
        };
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let v = Json::parse(&text).map_err(|e| {
                Error::Kb(format!(
                    "corrupt knowledge base {}: {e:?}",
                    path.display()
                ))
            })?;
            for e in v.get("profiles")?.as_arr().unwrap_or(&[]) {
                kb.entries.push(Profile::from_json(e)?);
            }
            // Optional (PR 10): per-class cost models. Absent in KBs
            // written before the irregular tier.
            if let Ok(models) = v.get("class_models") {
                if let Some(obj) = models.as_obj() {
                    for (label, m) in obj {
                        kb.class_models
                            .insert(label.clone(), ClassModel::from_json(m)?);
                    }
                }
            }
        }
        Ok(kb)
    }

    /// Open (or create) a durable store-backed KB (DESIGN.md §2.9):
    /// entries load from the store's merged view, matching-digest records
    /// as exact (warm-start) entries, foreign-digest records as
    /// derivation hints; `store()` then writes through incrementally.
    pub fn open_store(dir: &Path, manifest_digest: &str) -> Result<KnowledgeBase> {
        let st = KbStore::open(dir, manifest_digest)?;
        let mut kb = KnowledgeBase {
            manifest_digest: Some(manifest_digest.to_string()),
            ..KnowledgeBase::default()
        };
        let recs: Vec<StoreRecord> = st.records().cloned().collect();
        kb.kb_store = Some(st);
        for rec in &recs {
            kb.absorb_record(rec, manifest_digest);
        }
        Ok(kb)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Foreign-manifest derivation hints currently held.
    pub fn hint_count(&self) -> usize {
        self.hints.len()
    }

    pub fn store_backed(&self) -> bool {
        self.kb_store.is_some()
    }

    /// Store epoch of the durable backing, if any.
    pub fn store_epoch(&self) -> Option<u64> {
        self.kb_store.as_ref().map(|s| s.epoch())
    }

    /// Local manifest digest: the store's when backed, else whatever
    /// [`ensure_manifest_digest`](KnowledgeBase::ensure_manifest_digest)
    /// recorded, else empty (matches nothing).
    fn local_digest(&self) -> String {
        if let Some(st) = &self.kb_store {
            return st.manifest_digest().to_string();
        }
        self.manifest_digest.clone().unwrap_or_default()
    }

    /// Record the local platform digest if none is known yet — lets
    /// snapshot imports into in-memory KBs classify exact vs hint.
    pub fn ensure_manifest_digest(&mut self, digest: &str) {
        if self.manifest_digest.is_none() {
            self.manifest_digest = Some(digest.to_string());
        }
    }

    /// Persist. Store-backed: flush pending write-through records and
    /// absorb concurrent flushes. Legacy JSON: atomic whole-file rewrite
    /// (write-temp + fsync + rename), so a crash mid-save can never torn
    /// -write the KB. No-op for plain in-memory KBs.
    pub fn save(&mut self) -> Result<()> {
        if self.kb_store.is_some() {
            self.sync_store()?;
            return Ok(());
        }
        if let Some(path) = self.path.clone() {
            let mut fields = vec![(
                "profiles",
                Json::arr(self.entries.iter().map(|p| p.to_json()).collect()),
            )];
            // Class models only appear once observed, keeping pre-PR-10
            // KB files byte-identical on round-trip.
            if !self.class_models.is_empty() {
                fields.push((
                    "class_models",
                    Json::Obj(
                        self.class_models
                            .iter()
                            .map(|(k, m)| (k.clone(), m.to_json()))
                            .collect(),
                    ),
                ));
            }
            let v = Json::obj(fields);
            atomic_write(&path, v.to_string_pretty().as_bytes())?;
        }
        Ok(())
    }

    /// Flush pending write-through records and, when another process has
    /// flushed segments since our last look (epoch change), absorb them.
    /// Returns the number of records absorbed from disk.
    pub fn sync_store(&mut self) -> Result<usize> {
        let Some(mut st) = self.kb_store.take() else {
            return Ok(0);
        };
        let result = self.sync_inner(&mut st);
        self.kb_store = Some(st);
        result
    }

    fn sync_inner(&mut self, st: &mut KbStore) -> Result<usize> {
        st.flush()?;
        if st.stale()? {
            st.reload()?;
        }
        // Absorb the store's full merged view, not just what this reload
        // folded: `flush` itself reloads concurrent segments first (to
        // advance past them), and those records must reach the entries
        // too. `absorb_record` is idempotent, so re-offering known
        // records changes nothing.
        let local = st.manifest_digest().to_string();
        let recs: Vec<StoreRecord> = st.records().cloned().collect();
        let mut absorbed = 0;
        for rec in &recs {
            if self.absorb_record(rec, &local) {
                absorbed += 1;
            }
        }
        Ok(absorbed)
    }

    /// Fold one store/snapshot record into the in-memory view: matching
    /// digest → exact entry (marked imported) if strictly better than or
    /// new to the current entries; foreign digest → derivation hint
    /// (deduped per content key under the store's total order). Returns
    /// whether anything changed.
    fn absorb_record(&mut self, rec: &StoreRecord, local: &str) -> bool {
        if !local.is_empty() && rec.manifest_digest == local {
            let key = pair_key(&rec.profile.sct_id, &rec.profile.workload.id());
            match self.entries.iter_mut().find(|p| {
                p.sct_id == rec.profile.sct_id
                    && p.workload.id() == rec.profile.workload.id()
            }) {
                Some(existing) => {
                    if rec.profile.best_time < existing.best_time {
                        *existing = rec.profile.clone();
                        self.imported.insert(key);
                        true
                    } else {
                        false
                    }
                }
                None => {
                    self.entries.push(rec.profile.clone());
                    self.imported.insert(key);
                    true
                }
            }
        } else {
            match self.hints.iter_mut().find(|h| h.key == rec.key) {
                Some(existing) => {
                    if store::replaces(rec, existing) {
                        *existing = rec.clone();
                        true
                    } else {
                        false
                    }
                }
                None => {
                    self.hints.push(rec.clone());
                    true
                }
            }
        }
    }

    /// Import a snapshot: matching-digest records become exact entries
    /// (warm-start), others derivation hints; everything is staged into
    /// the durable store when one backs this KB. Returns
    /// (exact entries absorbed, hints absorbed).
    pub fn import_snapshot(&mut self, snap: &KbSnapshot) -> (usize, usize) {
        let local = self.local_digest();
        let (mut exact, mut hints) = (0usize, 0usize);
        for rec in snap.records() {
            let matches = !local.is_empty() && rec.manifest_digest == local;
            if self.absorb_record(rec, &local) {
                if matches {
                    exact += 1;
                } else {
                    hints += 1;
                }
            }
            if let Some(st) = &mut self.kb_store {
                st.stage_record(rec.clone());
            }
        }
        (exact, hints)
    }

    /// Export the full known record set (entries under the local digest
    /// plus foreign hints; the store's merged view when backed) as a
    /// canonical snapshot.
    pub fn export_snapshot(&self) -> KbSnapshot {
        if let Some(st) = &self.kb_store {
            return KbSnapshot::from_store(st);
        }
        let local = self.local_digest();
        let recs = self
            .entries
            .iter()
            .map(|p| StoreRecord::new(p.clone(), &local))
            .chain(self.hints.iter().cloned());
        KbSnapshot::from_records(recs)
    }

    /// Did the current entry for this pair come from the store / an
    /// imported snapshot (i.e. is a hit on it a *warm-start* hit)?
    pub fn is_imported(&self, sct_id: &str, workload: &Workload) -> bool {
        self.imported.contains(&pair_key(sct_id, &workload.id()))
    }

    /// Store a profile, keeping only the best time per (SCT, workload);
    /// write-through to the durable store when one backs this KB.
    pub fn store(&mut self, profile: Profile) {
        let accepted = match self.entries.iter_mut().find(|p| {
            p.sct_id == profile.sct_id && p.workload.id() == profile.workload.id()
        }) {
            Some(existing) => {
                if profile.best_time <= existing.best_time
                    || profile.origin == ProfileOrigin::Refined
                {
                    *existing = profile.clone();
                    true
                } else {
                    false
                }
            }
            None => {
                self.entries.push(profile.clone());
                true
            }
        };
        if accepted {
            // A local measurement now owns this pair.
            self.imported
                .remove(&pair_key(&profile.sct_id, &profile.workload.id()));
            if let Some(st) = &mut self.kb_store {
                st.stage(profile, None);
            }
        }
    }

    /// Exact lookup for a (SCT, workload) pair. Derivation hints are
    /// deliberately excluded: a foreign-manifest profile is never an
    /// exact hit.
    pub fn lookup(&self, sct_id: &str, workload: &Workload) -> Option<&Profile> {
        self.entries
            .iter()
            .find(|p| p.sct_id == sct_id && p.workload.id() == workload.id())
    }

    /// Entries plus foreign-manifest derivation hints: the profile pool
    /// the derivation scopes interpolate over.
    fn all_profiles(&self) -> impl Iterator<Item = &Profile> {
        self.entries
            .iter()
            .chain(self.hints.iter().map(|r| &r.profile))
    }

    /// Derive a configuration for an unseen pair (box "Derive work
    /// distribution"). Returns `None` when nothing of the same
    /// dimensionality exists yet.
    pub fn derive(&self, sct_id: &str, workload: &Workload) -> Option<FrameworkConfig> {
        if let Some(hit) = self.lookup(sct_id, workload) {
            return Some(hit.config.clone());
        }
        // Scope 1: same SCT.
        let same_sct: Vec<&Profile> = self
            .all_profiles()
            .filter(|p| {
                p.sct_id == sct_id
                    && p.workload.dimensionality() == workload.dimensionality()
            })
            .collect();
        if !same_sct.is_empty() {
            return Some(interpolate_config(&same_sct, workload));
        }
        // Scope 2: same workload, any SCT.
        let same_wl: Vec<&Profile> = self
            .all_profiles()
            .filter(|p| p.workload.id() == workload.id())
            .collect();
        if !same_wl.is_empty() {
            return Some(interpolate_config(&same_wl, workload));
        }
        // Scope 3: same dimensionality.
        let same_dim: Vec<&Profile> = self
            .all_profiles()
            .filter(|p| p.workload.dimensionality() == workload.dimensionality())
            .collect();
        if !same_dim.is_empty() {
            return Some(interpolate_config(&same_dim, workload));
        }
        None
    }

    pub fn entries(&self) -> &[Profile] {
        &self.entries
    }

    /// Best-known completion estimate for a (SCT, workload) pair — the
    /// cost side of the co-scheduling admission control (DESIGN.md §2.8).
    /// An exact entry's `best_time` when present; otherwise the best time
    /// of the *nearest* profile (by workload features, like
    /// [`interpolate_config`]'s discrete fields) over the same
    /// progressively-widening scopes [`KnowledgeBase::derive`] uses (same
    /// SCT and dimensionality, then same workload, then same
    /// dimensionality) — a scope *minimum* would price a large request at
    /// the smallest workload ever recorded. Entries only: foreign-manifest
    /// hints carry another machine's clock and would mis-price admission.
    /// `None` on a cold KB — callers fall back to an observed mean.
    ///
    /// Irregular classes (ROADMAP item 4): when there is no exact entry
    /// and the workload carries a non-Regular class with an observed
    /// [`ClassModel`], the class mean rescaled by element count wins over
    /// the size-only nearest-profile search — per-size interpolation has
    /// no way to see data-dependent cost, and the bench gate holds the
    /// class path to a strictly lower estimate error on sparse workloads.
    pub fn estimate_time(&self, sct_id: &str, workload: &Workload) -> Option<f64> {
        if let Some(p) = self.lookup(sct_id, workload) {
            return Some(p.best_time);
        }
        if workload.class != WorkloadClass::Regular {
            if let Some(est) = self.class_estimate(workload.class, workload.elems()) {
                return Some(est);
            }
        }
        self.estimate_time_size_only(sct_id, workload)
    }

    /// The pre-class estimate path: nearest profile by workload features
    /// over the derive scopes, blind to per-class cost models. Public so
    /// the bench gate can compare it against the class-aware estimate.
    pub fn estimate_time_size_only(&self, sct_id: &str, workload: &Workload) -> Option<f64> {
        if let Some(p) = self.lookup(sct_id, workload) {
            return Some(p.best_time);
        }
        let target = workload.features();
        let nearest = |pred: &dyn Fn(&Profile) -> bool| -> Option<f64> {
            self.entries
                .iter()
                .filter(|p| pred(p))
                .min_by(|a, b| {
                    let da = crate::util::linalg::dist(&a.workload.features(), &target);
                    let db = crate::util::linalg::dist(&b.workload.features(), &target);
                    da.partial_cmp(&db).unwrap()
                })
                .map(|p| p.best_time)
        };
        nearest(&|p: &Profile| {
            p.sct_id == sct_id && p.workload.dimensionality() == workload.dimensionality()
        })
        .or_else(|| nearest(&|p: &Profile| p.workload.id() == workload.id()))
        .or_else(|| {
            nearest(&|p: &Profile| {
                p.workload.dimensionality() == workload.dimensionality()
            })
        })
    }

    /// Cost estimate for a *fused batch* of requests (DESIGN.md §2.10):
    /// the fused graph drains every member on the same device set under
    /// one ready-set pass, so the batch is priced as its critical member
    /// plus a packed residual ([`pack_estimate`]) — not as the serialized
    /// sum admission would charge for solo drains. `None` when any member
    /// is cold (callers fall back to observed means, same as solo
    /// admission).
    pub fn estimate_batch(&self, members: &[(&str, &Workload)]) -> Option<f64> {
        let ests = members
            .iter()
            .map(|(id, w)| self.estimate_time(id, w))
            .collect::<Option<Vec<f64>>>()?;
        Some(pack_estimate(&ests))
    }

    /// Fold one observed run into the class's cost model. Regular
    /// workloads are excluded by the caller convention (their per-size
    /// interpolation is already accurate), but folding them is harmless.
    pub fn observe_class(&mut self, class: WorkloadClass, elems: u64, secs: f64) {
        self.class_models
            .entry(class.label().to_string())
            .or_default()
            .observe(elems, secs);
    }

    /// Class-model completion estimate: observed mean seconds-per-element
    /// rescaled to `elems`. `None` before any observation of the class.
    pub fn class_estimate(&self, class: WorkloadClass, elems: u64) -> Option<f64> {
        self.class_models
            .get(class.label())
            .and_then(|m| m.mean())
            .map(|spe| spe * elems as f64)
    }

    /// The class's running model, when observed (dispersion inspection
    /// for tests and the bench gate).
    pub fn class_model(&self, class: WorkloadClass) -> Option<&ClassModel> {
        self.class_models.get(class.label())
    }
}

/// How much of a fused batch's non-critical work the dataflow drain packs
/// into slots the critical member leaves idle: the residual beyond the
/// longest member is charged at this fraction. 1.0 would price the batch
/// as the serialized sum (no fusion benefit); the dataflow drain's
/// cross-member overlap lands well below that for leaning-diverse members,
/// so admission prices batches optimistically but still monotonically in
/// member count.
pub const BATCH_PACK_FACTOR: f64 = 0.6;

/// Fused-batch completion estimate from per-member solo estimates: the
/// critical (longest) member plus the packed residual of the rest.
pub fn pack_estimate(member_secs: &[f64]) -> f64 {
    let max = member_secs.iter().copied().fold(0.0, f64::max);
    let sum: f64 = member_secs.iter().sum();
    max + BATCH_PACK_FACTOR * (sum - max)
}

/// Interpolate a configuration from scoped profiles: continuous fields
/// (cpu_share) via RBF (dims <= 3) or inverse-distance NN; discrete fields
/// (fission, overlap, wgs) from the nearest neighbour.
fn interpolate_config(scope: &[&Profile], workload: &Workload) -> FrameworkConfig {
    let target = workload.features();
    let dims = workload.dimensionality();

    // Nearest profile for the discrete dimensions.
    let nearest = scope
        .iter()
        .min_by(|a, b| {
            let da = crate::util::linalg::dist(&a.workload.features(), &target);
            let db = crate::util::linalg::dist(&b.workload.features(), &target);
            da.partial_cmp(&db).unwrap()
        })
        .unwrap();

    let points: Vec<Vec<f64>> = scope.iter().map(|p| p.workload.features()).collect();
    let shares: Vec<f64> = scope.iter().map(|p| p.config.cpu_share).collect();
    let share = if dims <= 3 && points.len() >= 2 {
        interp::rbf_interpolate(&points, &shares, &target)
            .unwrap_or(nearest.config.cpu_share)
    } else {
        interp::nearest_neighbour(&points, &shares, &target)
            .unwrap_or(nearest.config.cpu_share)
    }
    .clamp(0.0, 1.0);

    FrameworkConfig {
        fission: nearest.config.fission,
        overlap: nearest.config.overlap.clone(),
        wgs: nearest.config.wgs,
        cpu_share: share,
    }
}

/// Convenience: a quick profile value for tests/benches.
pub fn mk_profile(
    sct_id: &str,
    workload: Workload,
    fission: FissionLevel,
    overlap: Vec<u32>,
    cpu_share: f64,
    best_time: f64,
) -> Profile {
    Profile {
        sct_id: sct_id.to_string(),
        workload,
        config: FrameworkConfig {
            fission,
            overlap,
            wgs: 256,
            cpu_share,
        },
        best_time,
        origin: ProfileOrigin::Built,
    }
}

impl std::fmt::Debug for KnowledgeBase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KnowledgeBase({} profiles, {} hints{})",
            self.entries.len(),
            self.hints.len(),
            if self.kb_store.is_some() {
                ", store-backed"
            } else {
                ""
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(h: u64, w: u64) -> Workload {
        Workload::d2(h, w)
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("marrow_kb_{tag}_{}", std::process::id()))
    }

    #[test]
    fn store_keeps_best() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.2, 2.0));
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L3, vec![4], 0.3, 1.0));
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L1, vec![4], 0.4, 5.0));
        assert_eq!(kb.len(), 1);
        let p = kb.lookup("f", &wl(1024, 1024)).unwrap();
        assert_eq!(p.config.fission, FissionLevel::L3);
    }

    #[test]
    fn exact_lookup_wins_over_interpolation() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.2, 1.0));
        let cfg = kb.derive("f", &wl(1024, 1024)).unwrap();
        assert_eq!(cfg.cpu_share, 0.2);
    }

    #[test]
    fn derive_interpolates_between_sizes() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.10, 1.0));
        kb.store(mk_profile("f", wl(4096, 4096), FissionLevel::L2, vec![4], 0.30, 1.0));
        let cfg = kb.derive("f", &wl(2048, 2048)).unwrap();
        assert!(
            cfg.cpu_share > 0.10 && cfg.cpu_share < 0.30,
            "share {}",
            cfg.cpu_share
        );
    }

    #[test]
    fn derive_scope_narrows_to_other_scts() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("other", wl(2048, 2048), FissionLevel::L1, vec![3], 0.25, 1.0));
        // Unknown SCT but same workload: scope 2.
        let cfg = kb.derive("fresh", &wl(2048, 2048)).unwrap();
        assert_eq!(cfg.fission, FissionLevel::L1);
        assert!((cfg.cpu_share - 0.25).abs() < 1e-9);
    }

    #[test]
    fn derive_falls_back_to_dimensionality() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("a", wl(512, 512), FissionLevel::L3, vec![2], 0.4, 1.0));
        let cfg = kb.derive("b", &wl(999, 777)).unwrap();
        assert_eq!(cfg.fission, FissionLevel::L3);
    }

    #[test]
    fn derive_none_for_empty_or_wrong_dim() {
        let kb = KnowledgeBase::in_memory();
        assert!(kb.derive("x", &wl(10, 10)).is_none());
        let mut kb2 = KnowledgeBase::in_memory();
        kb2.store(mk_profile("a", Workload::d1(100), FissionLevel::L1, vec![], 1.0, 1.0));
        assert!(kb2.derive("a", &wl(10, 10)).is_none());
    }

    #[test]
    fn estimate_time_narrows_scope_like_derive() {
        let mut kb = KnowledgeBase::in_memory();
        assert!(kb.estimate_time("f", &wl(1024, 1024)).is_none());
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.2, 2.5));
        // Exact hit.
        assert_eq!(kb.estimate_time("f", &wl(1024, 1024)), Some(2.5));
        // Same SCT, other size: the *nearest* profile's time, so a big
        // request is not priced at the smallest workload on record.
        kb.store(mk_profile("f", wl(4096, 4096), FissionLevel::L2, vec![4], 0.2, 9.0));
        assert_eq!(kb.estimate_time("f", &wl(1500, 1500)), Some(2.5));
        assert_eq!(kb.estimate_time("f", &wl(3500, 3500)), Some(9.0));
        // Unknown SCT of the same dimensionality still estimates.
        assert_eq!(kb.estimate_time("fresh", &wl(1500, 1500)), Some(2.5));
        // Wrong dimensionality stays cold.
        assert!(kb.estimate_time("f", &Workload::d1(64)).is_none());
    }

    #[test]
    fn batch_estimate_prices_fusion_below_the_sum() {
        let mut kb = KnowledgeBase::in_memory();
        let (a, b) = (wl(1024, 1024), wl(2048, 2048));
        assert!(kb.estimate_batch(&[("f", &a)]).is_none(), "cold KB");
        kb.store(mk_profile("f", a.clone(), FissionLevel::L2, vec![4], 0.2, 2.0));
        kb.store(mk_profile("f", b.clone(), FissionLevel::L2, vec![4], 0.2, 6.0));
        // A singleton batch is the solo estimate.
        assert_eq!(kb.estimate_batch(&[("f", &a)]), Some(2.0));
        // Critical member + packed residual: strictly between max and sum.
        let est = kb.estimate_batch(&[("f", &a), ("f", &b)]).unwrap();
        assert!(est > 6.0 && est < 8.0, "est {est}");
        assert!((est - pack_estimate(&[2.0, 6.0])).abs() < 1e-12);
        // Any cold member poisons the whole batch estimate.
        assert!(kb
            .estimate_batch(&[("f", &a), ("g", &Workload::d1(7))])
            .is_none());
    }

    #[test]
    fn class_model_beats_size_only_on_irregular_workloads() {
        use crate::data::workload::WorkloadClass;
        let mut kb = KnowledgeBase::in_memory();
        // One small sparse profile on record: the size-only nearest search
        // prices every sparse request at its (tiny) best_time.
        kb.store(mk_profile(
            "spmv",
            Workload::d1(256).with_class(WorkloadClass::Sparse),
            FissionLevel::L2,
            vec![4],
            0.2,
            0.001,
        ));
        // Observed sparse runs: ~2 us/element.
        for elems in [256u64, 1024, 4096] {
            kb.observe_class(WorkloadClass::Sparse, elems, elems as f64 * 2e-6);
        }
        let big = Workload::d1(65_536).with_class(WorkloadClass::Sparse);
        let truth = 65_536.0 * 2e-6;
        let class_aware = kb.estimate_time("spmv", &big).unwrap();
        let size_only = kb.estimate_time_size_only("spmv", &big).unwrap();
        assert!(
            (class_aware - truth).abs() < (size_only - truth).abs(),
            "class {class_aware} vs size-only {size_only} (truth {truth})"
        );
        // Exact entries still win over the model.
        let small = Workload::d1(256).with_class(WorkloadClass::Sparse);
        assert_eq!(kb.estimate_time("spmv", &small), Some(0.001));
        // Regular workloads never consult the class model.
        assert!(kb.estimate_time("other", &Workload::d1(64)).is_none());
        // Dispersion of a constant-rate model is ~0.
        assert!(kb.class_model(WorkloadClass::Sparse).unwrap().dispersion() < 1e-9);
        assert!(kb.class_estimate(WorkloadClass::Traversal, 100).is_none());
    }

    #[test]
    fn class_models_persist_in_legacy_json() {
        use crate::data::workload::WorkloadClass;
        let path = tmp("classmodels.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut kb = KnowledgeBase::open(&path).unwrap();
            kb.observe_class(WorkloadClass::Divergent, 1000, 0.004);
            kb.observe_class(WorkloadClass::Divergent, 1000, 0.008);
            kb.save().unwrap();
        }
        let kb = KnowledgeBase::open(&path).unwrap();
        let m = kb.class_model(WorkloadClass::Divergent).unwrap();
        assert_eq!(m.count, 2);
        assert!((kb.class_estimate(WorkloadClass::Divergent, 1000).unwrap() - 0.006).abs() < 1e-12);
        assert!(m.dispersion() > 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistence_roundtrip() {
        let path = tmp("roundtrip.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut kb = KnowledgeBase::open(&path).unwrap();
            kb.store(mk_profile("f", wl(1024, 512), FissionLevel::Numa, vec![2, 3], 0.15, 0.5));
            kb.save().unwrap();
        }
        let kb = KnowledgeBase::open(&path).unwrap();
        assert_eq!(kb.len(), 1);
        let p = kb.lookup("f", &wl(1024, 512)).unwrap();
        assert_eq!(p.config.fission, FissionLevel::Numa);
        assert_eq!(p.config.overlap, vec![2, 3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_kb_file_is_reported_not_empty() {
        let path = tmp("corrupt.json");
        // Torn write: a truncated prefix of a valid KB.
        std::fs::write(&path, "{\n  \"profiles\": [\n    {\"sct_id\": \"f").unwrap();
        let err = KnowledgeBase::open(&path);
        assert!(err.is_err(), "truncated KB must not load as empty");
        assert!(
            format!("{:?}", err.unwrap_err()).contains("corrupt"),
            "error should name the corruption"
        );
        // Valid JSON of the wrong shape is also an error, not empty.
        std::fs::write(&path, "{\"x\": 1}").unwrap();
        assert!(KnowledgeBase::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_leaves_no_temp_residue() {
        let dir = tmp("atomic_dir");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kb.json");
        let mut kb = KnowledgeBase::open(&path).unwrap();
        kb.store(mk_profile("f", wl(64, 64), FissionLevel::L2, vec![4], 0.2, 1.0));
        kb.save().unwrap();
        kb.save().unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["kb.json".to_string()], "residue: {names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_backed_write_through_roundtrip() {
        let dir = tmp("writethrough");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut kb = KnowledgeBase::open_store(&dir, "m0").unwrap();
            kb.store(mk_profile("f", wl(256, 256), FissionLevel::L2, vec![4], 0.2, 1.0));
            assert!(!kb.is_imported("f", &wl(256, 256)), "local build is not imported");
            kb.save().unwrap();
        }
        let kb = KnowledgeBase::open_store(&dir, "m0").unwrap();
        assert_eq!(kb.len(), 1);
        assert!(kb.lookup("f", &wl(256, 256)).is_some());
        assert!(
            kb.is_imported("f", &wl(256, 256)),
            "a reloaded entry is warm-start provenance"
        );
        // A different manifest digest sees the record as a hint only.
        let other = KnowledgeBase::open_store(&dir, "m1").unwrap();
        assert_eq!(other.len(), 0);
        assert_eq!(other.hint_count(), 1);
        assert!(other.lookup("f", &wl(256, 256)).is_none());
        assert!(other.derive("f", &wl(256, 256)).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_import_classifies_by_digest() {
        let mut src = KnowledgeBase::in_memory();
        src.ensure_manifest_digest("mach-A");
        src.store(mk_profile("f", wl(128, 128), FissionLevel::L2, vec![4], 0.2, 1.0));
        let snap = src.export_snapshot();
        assert_eq!(snap.len(), 1);

        let mut same = KnowledgeBase::in_memory();
        same.ensure_manifest_digest("mach-A");
        assert_eq!(same.import_snapshot(&snap), (1, 0));
        assert!(same.lookup("f", &wl(128, 128)).is_some());
        assert!(same.is_imported("f", &wl(128, 128)));

        let mut other = KnowledgeBase::in_memory();
        other.ensure_manifest_digest("mach-B");
        assert_eq!(other.import_snapshot(&snap), (0, 1));
        assert!(other.lookup("f", &wl(128, 128)).is_none());
        assert!(other.derive("f", &wl(128, 128)).is_some(), "hints feed derivation");
        // Importing twice changes nothing (idempotent).
        assert_eq!(other.import_snapshot(&snap), (0, 0));
    }

    #[test]
    fn local_store_clears_imported_mark() {
        let mut src = KnowledgeBase::in_memory();
        src.ensure_manifest_digest("m");
        src.store(mk_profile("f", wl(64, 64), FissionLevel::L2, vec![4], 0.2, 5.0));
        let snap = src.export_snapshot();
        let mut kb = KnowledgeBase::in_memory();
        kb.ensure_manifest_digest("m");
        kb.import_snapshot(&snap);
        assert!(kb.is_imported("f", &wl(64, 64)));
        kb.store(mk_profile("f", wl(64, 64), FissionLevel::L2, vec![4], 0.2, 4.0));
        assert!(!kb.is_imported("f", &wl(64, 64)));
    }
}
