//! The Knowledge Base (Section 3.2.3): stores the best-known configuration
//! per (SCT, workload) pair, persists to JSON, and *derives* configurations
//! for unseen pairs via multidimensional interpolation of scattered data —
//! an RBF network for workspaces of dimension 1-3, nearest-neighbour above.
//!
//! Derivation narrows scope progressively: configurations of the same SCT
//! first; failing that, configurations of the same workload regardless of
//! SCT; failing that, any workload of the same dimensionality.

pub mod interp;

use std::path::{Path, PathBuf};

use crate::data::workload::Workload;
use crate::error::Result;
use crate::platform::cpu::FissionLevel;
use crate::tuner::profile::{FrameworkConfig, Profile, ProfileOrigin};
use crate::util::json::Json;

/// The knowledge base. `Clone` snapshots the current profiles (used when
/// extracting a KB that other sessions still share).
#[derive(Clone, Default)]
pub struct KnowledgeBase {
    entries: Vec<Profile>,
    path: Option<PathBuf>,
}

impl KnowledgeBase {
    pub fn in_memory() -> KnowledgeBase {
        KnowledgeBase::default()
    }

    /// Open (or create) a JSON-backed KB.
    pub fn open(path: &Path) -> Result<KnowledgeBase> {
        let mut kb = KnowledgeBase {
            entries: Vec::new(),
            path: Some(path.to_path_buf()),
        };
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let v = Json::parse(&text)?;
            for e in v.get("profiles")?.as_arr().unwrap_or(&[]) {
                kb.entries.push(Profile::from_json(e)?);
            }
        }
        Ok(kb)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Persist to the backing file (no-op for in-memory KBs).
    pub fn save(&self) -> Result<()> {
        if let Some(path) = &self.path {
            let v = Json::obj(vec![(
                "profiles",
                Json::arr(self.entries.iter().map(|p| p.to_json()).collect()),
            )]);
            std::fs::write(path, v.to_string_pretty())?;
        }
        Ok(())
    }

    /// Store a profile, keeping only the best time per (SCT, workload).
    pub fn store(&mut self, profile: Profile) {
        if let Some(existing) = self.entries.iter_mut().find(|p| {
            p.sct_id == profile.sct_id && p.workload.id() == profile.workload.id()
        }) {
            if profile.best_time <= existing.best_time
                || profile.origin == ProfileOrigin::Refined
            {
                *existing = profile;
            }
        } else {
            self.entries.push(profile);
        }
    }

    /// Exact lookup for a (SCT, workload) pair.
    pub fn lookup(&self, sct_id: &str, workload: &Workload) -> Option<&Profile> {
        self.entries
            .iter()
            .find(|p| p.sct_id == sct_id && p.workload.id() == workload.id())
    }

    /// Derive a configuration for an unseen pair (box "Derive work
    /// distribution"). Returns `None` when nothing of the same
    /// dimensionality exists yet.
    pub fn derive(&self, sct_id: &str, workload: &Workload) -> Option<FrameworkConfig> {
        if let Some(hit) = self.lookup(sct_id, workload) {
            return Some(hit.config.clone());
        }
        // Scope 1: same SCT.
        let same_sct: Vec<&Profile> = self
            .entries
            .iter()
            .filter(|p| {
                p.sct_id == sct_id
                    && p.workload.dimensionality() == workload.dimensionality()
            })
            .collect();
        if !same_sct.is_empty() {
            return Some(interpolate_config(&same_sct, workload));
        }
        // Scope 2: same workload, any SCT.
        let same_wl: Vec<&Profile> = self
            .entries
            .iter()
            .filter(|p| p.workload.id() == workload.id())
            .collect();
        if !same_wl.is_empty() {
            return Some(interpolate_config(&same_wl, workload));
        }
        // Scope 3: same dimensionality.
        let same_dim: Vec<&Profile> = self
            .entries
            .iter()
            .filter(|p| p.workload.dimensionality() == workload.dimensionality())
            .collect();
        if !same_dim.is_empty() {
            return Some(interpolate_config(&same_dim, workload));
        }
        None
    }

    pub fn entries(&self) -> &[Profile] {
        &self.entries
    }

    /// Best-known completion estimate for a (SCT, workload) pair — the
    /// cost side of the co-scheduling admission control (DESIGN.md §2.8).
    /// An exact entry's `best_time` when present; otherwise the best time
    /// of the *nearest* profile (by workload features, like
    /// [`interpolate_config`]'s discrete fields) over the same
    /// progressively-widening scopes [`KnowledgeBase::derive`] uses (same
    /// SCT and dimensionality, then same workload, then same
    /// dimensionality) — a scope *minimum* would price a large request at
    /// the smallest workload ever recorded. `None` on a cold KB — callers
    /// fall back to an observed mean.
    pub fn estimate_time(&self, sct_id: &str, workload: &Workload) -> Option<f64> {
        if let Some(p) = self.lookup(sct_id, workload) {
            return Some(p.best_time);
        }
        let target = workload.features();
        let nearest = |pred: &dyn Fn(&Profile) -> bool| -> Option<f64> {
            self.entries
                .iter()
                .filter(|p| pred(p))
                .min_by(|a, b| {
                    let da = crate::util::linalg::dist(&a.workload.features(), &target);
                    let db = crate::util::linalg::dist(&b.workload.features(), &target);
                    da.partial_cmp(&db).unwrap()
                })
                .map(|p| p.best_time)
        };
        nearest(&|p: &Profile| {
            p.sct_id == sct_id && p.workload.dimensionality() == workload.dimensionality()
        })
        .or_else(|| nearest(&|p: &Profile| p.workload.id() == workload.id()))
        .or_else(|| {
            nearest(&|p: &Profile| {
                p.workload.dimensionality() == workload.dimensionality()
            })
        })
    }
}

/// Interpolate a configuration from scoped profiles: continuous fields
/// (cpu_share) via RBF (dims <= 3) or inverse-distance NN; discrete fields
/// (fission, overlap, wgs) from the nearest neighbour.
fn interpolate_config(scope: &[&Profile], workload: &Workload) -> FrameworkConfig {
    let target = workload.features();
    let dims = workload.dimensionality();

    // Nearest profile for the discrete dimensions.
    let nearest = scope
        .iter()
        .min_by(|a, b| {
            let da = crate::util::linalg::dist(&a.workload.features(), &target);
            let db = crate::util::linalg::dist(&b.workload.features(), &target);
            da.partial_cmp(&db).unwrap()
        })
        .unwrap();

    let points: Vec<Vec<f64>> = scope.iter().map(|p| p.workload.features()).collect();
    let shares: Vec<f64> = scope.iter().map(|p| p.config.cpu_share).collect();
    let share = if dims <= 3 && points.len() >= 2 {
        interp::rbf_interpolate(&points, &shares, &target)
            .unwrap_or(nearest.config.cpu_share)
    } else {
        interp::nearest_neighbour(&points, &shares, &target)
            .unwrap_or(nearest.config.cpu_share)
    }
    .clamp(0.0, 1.0);

    FrameworkConfig {
        fission: nearest.config.fission,
        overlap: nearest.config.overlap.clone(),
        wgs: nearest.config.wgs,
        cpu_share: share,
    }
}

/// Convenience: a quick profile value for tests/benches.
pub fn mk_profile(
    sct_id: &str,
    workload: Workload,
    fission: FissionLevel,
    overlap: Vec<u32>,
    cpu_share: f64,
    best_time: f64,
) -> Profile {
    Profile {
        sct_id: sct_id.to_string(),
        workload,
        config: FrameworkConfig {
            fission,
            overlap,
            wgs: 256,
            cpu_share,
        },
        best_time,
        origin: ProfileOrigin::Built,
    }
}

impl std::fmt::Debug for KnowledgeBase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KnowledgeBase({} profiles)", self.entries.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(h: u64, w: u64) -> Workload {
        Workload::d2(h, w)
    }

    #[test]
    fn store_keeps_best() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.2, 2.0));
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L3, vec![4], 0.3, 1.0));
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L1, vec![4], 0.4, 5.0));
        assert_eq!(kb.len(), 1);
        let p = kb.lookup("f", &wl(1024, 1024)).unwrap();
        assert_eq!(p.config.fission, FissionLevel::L3);
    }

    #[test]
    fn exact_lookup_wins_over_interpolation() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.2, 1.0));
        let cfg = kb.derive("f", &wl(1024, 1024)).unwrap();
        assert_eq!(cfg.cpu_share, 0.2);
    }

    #[test]
    fn derive_interpolates_between_sizes() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.10, 1.0));
        kb.store(mk_profile("f", wl(4096, 4096), FissionLevel::L2, vec![4], 0.30, 1.0));
        let cfg = kb.derive("f", &wl(2048, 2048)).unwrap();
        assert!(
            cfg.cpu_share > 0.10 && cfg.cpu_share < 0.30,
            "share {}",
            cfg.cpu_share
        );
    }

    #[test]
    fn derive_scope_narrows_to_other_scts() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("other", wl(2048, 2048), FissionLevel::L1, vec![3], 0.25, 1.0));
        // Unknown SCT but same workload: scope 2.
        let cfg = kb.derive("fresh", &wl(2048, 2048)).unwrap();
        assert_eq!(cfg.fission, FissionLevel::L1);
        assert!((cfg.cpu_share - 0.25).abs() < 1e-9);
    }

    #[test]
    fn derive_falls_back_to_dimensionality() {
        let mut kb = KnowledgeBase::in_memory();
        kb.store(mk_profile("a", wl(512, 512), FissionLevel::L3, vec![2], 0.4, 1.0));
        let cfg = kb.derive("b", &wl(999, 777)).unwrap();
        assert_eq!(cfg.fission, FissionLevel::L3);
    }

    #[test]
    fn derive_none_for_empty_or_wrong_dim() {
        let kb = KnowledgeBase::in_memory();
        assert!(kb.derive("x", &wl(10, 10)).is_none());
        let mut kb2 = KnowledgeBase::in_memory();
        kb2.store(mk_profile("a", Workload::d1(100), FissionLevel::L1, vec![], 1.0, 1.0));
        assert!(kb2.derive("a", &wl(10, 10)).is_none());
    }

    #[test]
    fn estimate_time_narrows_scope_like_derive() {
        let mut kb = KnowledgeBase::in_memory();
        assert!(kb.estimate_time("f", &wl(1024, 1024)).is_none());
        kb.store(mk_profile("f", wl(1024, 1024), FissionLevel::L2, vec![4], 0.2, 2.5));
        // Exact hit.
        assert_eq!(kb.estimate_time("f", &wl(1024, 1024)), Some(2.5));
        // Same SCT, other size: the *nearest* profile's time, so a big
        // request is not priced at the smallest workload on record.
        kb.store(mk_profile("f", wl(4096, 4096), FissionLevel::L2, vec![4], 0.2, 9.0));
        assert_eq!(kb.estimate_time("f", &wl(1500, 1500)), Some(2.5));
        assert_eq!(kb.estimate_time("f", &wl(3500, 3500)), Some(9.0));
        // Unknown SCT of the same dimensionality still estimates.
        assert_eq!(kb.estimate_time("fresh", &wl(1500, 1500)), Some(2.5));
        // Wrong dimensionality stays cold.
        assert!(kb.estimate_time("f", &Workload::d1(64)).is_none());
    }

    #[test]
    fn persistence_roundtrip() {
        let path = std::env::temp_dir().join("marrow_kb_test.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut kb = KnowledgeBase::open(&path).unwrap();
            kb.store(mk_profile("f", wl(1024, 512), FissionLevel::Numa, vec![2, 3], 0.15, 0.5));
            kb.save().unwrap();
        }
        let kb = KnowledgeBase::open(&path).unwrap();
        assert_eq!(kb.len(), 1);
        let p = kb.lookup("f", &wl(1024, 512)).unwrap();
        assert_eq!(p.config.fission, FissionLevel::Numa);
        assert_eq!(p.config.overlap, vec![2, 3]);
        let _ = std::fs::remove_file(&path);
    }
}
