//! Host-side data containers and synthetic workload generators.

pub mod image;
pub mod irregular;
pub mod vector;
pub mod workload;

pub use vector::{ArgValue, Merge, ScalarTrait, Transfer, VectorArg};
pub use workload::Workload;
