//! `Vector` data containers and kernel argument values (Section 2.1 / 3.4).
//!
//! Marrow classifies kernel parameters as vectors or scalars, mutable or
//! immutable, partitionable or COPY. Partition-sensitive scalars can carry
//! the `Size` / `Offset` traits, instantiated by the runtime with the
//! current partition's size/offset. Multi-device executions produce partial
//! results combined by *merging* functions.

use crate::error::{Error, Result};

/// Data-transfer mode of a vector argument (Section 3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transfer {
    /// Partitioned across devices under the locality-aware decomposition.
    Partition,
    /// Replicated integrally to every device (global snapshot semantics).
    Copy,
}

/// Partition-sensitive scalar traits (Section 3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalarTrait {
    /// Plain partition-invariant value.
    Bound,
    /// Instantiated with the size (in elements) of the current partition.
    Size,
    /// Instantiated with the offset (in epu units) of the current partition.
    Offset,
    /// Instantiated with `base + partition offset` — used to decorrelate
    /// per-partition RNG streams (gaussian noise kernel).
    SeededOffset,
}

/// Predefined merging functions for partial scalar results (Section 3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Merge {
    Add,
    Sub,
    Mul,
    Div,
    /// Concatenate partition outputs in partition order (vector results).
    Concat,
}

impl Merge {
    /// Fold two f32 partial results.
    pub fn fold(self, a: f32, b: f32) -> f32 {
        match self {
            Merge::Add => a + b,
            Merge::Sub => a - b,
            Merge::Mul => a * b,
            Merge::Div => a / b,
            Merge::Concat => a, // not meaningful for scalars
        }
    }
}

/// Host-side typed buffer.
#[derive(Clone, Debug)]
pub enum ArgValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl ArgValue {
    pub fn len(&self) -> usize {
        match self {
            ArgValue::F32(v) => v.len(),
            ArgValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            ArgValue::F32(v) => Ok(v),
            _ => Err(Error::Spec("expected f32 buffer".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            ArgValue::I32(v) => Ok(v),
            _ => Err(Error::Spec("expected i32 buffer".into())),
        }
    }

    /// Slice a sub-range (element granularity).
    pub fn slice(&self, start: usize, len: usize) -> ArgValue {
        match self {
            ArgValue::F32(v) => ArgValue::F32(v[start..start + len].to_vec()),
            ArgValue::I32(v) => ArgValue::I32(v[start..start + len].to_vec()),
        }
    }

    /// Cheap content probe for request fingerprinting: length plus 32
    /// elements sampled at even strides across the buffer (all of it when
    /// shorter). O(1) — it distinguishes different datasets of the same
    /// shape without hashing whole buffers; in-place rewrites are covered
    /// by [`VectorArg::bump_version`], not by this probe.
    pub fn probe(&self) -> u64 {
        const SAMPLES: usize = 32;
        let mut h: u64 = 0x9e3779b97f4a7c15 ^ self.len() as u64;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.rotate_left(17).wrapping_mul(0x100000001b3);
        };
        let n = self.len();
        let step = (n / SAMPLES).max(1);
        match self {
            ArgValue::F32(v) => {
                for x in v.iter().step_by(step).take(SAMPLES) {
                    mix(x.to_bits() as u64);
                }
                if let Some(last) = v.last() {
                    mix(last.to_bits() as u64);
                }
            }
            ArgValue::I32(v) => {
                for x in v.iter().step_by(step).take(SAMPLES) {
                    mix(*x as u32 as u64);
                }
                if let Some(last) = v.last() {
                    mix(*last as u32 as u64);
                }
            }
        }
        h
    }

    /// Exact content equality (same variant, same elements) — used by the
    /// Loop update path to detect which arguments the host actually
    /// rewrote, so untouched args keep their buffer residency.
    pub fn same_contents(&self, other: &ArgValue) -> bool {
        match (self, other) {
            (ArgValue::F32(a), ArgValue::F32(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (ArgValue::I32(a), ArgValue::I32(b)) => a == b,
            _ => false,
        }
    }
}

/// A vector argument to an execution request: the host object plus its
/// data-management contract.
#[derive(Clone, Debug)]
pub struct VectorArg {
    pub name: String,
    pub value: ArgValue,
    pub transfer: Transfer,
    /// Row size in elements: an epu unit of this vector spans
    /// `elems_per_unit` consecutive elements (e.g. one image line = width).
    pub elems_per_unit: u64,
    /// Residency version: bumped whenever the host rewrites `value` (e.g.
    /// a global-sync Loop update), so device-resident ranges of the old
    /// contents stop matching in the buffer-residency pool.
    pub version: u64,
}

impl VectorArg {
    pub fn partitioned_f32(name: &str, data: Vec<f32>, elems_per_unit: u64) -> VectorArg {
        VectorArg {
            name: name.to_string(),
            value: ArgValue::F32(data),
            transfer: Transfer::Partition,
            elems_per_unit,
            version: 0,
        }
    }

    pub fn copied_f32(name: &str, data: Vec<f32>) -> VectorArg {
        VectorArg {
            name: name.to_string(),
            value: ArgValue::F32(data),
            transfer: Transfer::Copy,
            elems_per_unit: 1,
            version: 0,
        }
    }

    /// Mark the vector's contents as rewritten by the host: resident
    /// copies of the previous version are no longer valid.
    pub fn bump_version(&mut self) {
        self.version += 1;
    }

    /// Number of epu units this vector holds.
    pub fn units(&self) -> u64 {
        self.value.len() as u64 / self.elems_per_unit.max(1)
    }

    /// Slice the units [start, start+len) (Partition mode only).
    pub fn slice_units(&self, start: u64, len: u64) -> Result<ArgValue> {
        if self.transfer != Transfer::Partition {
            return Err(Error::Spec(format!(
                "vector '{}' is COPY mode; cannot slice",
                self.name
            )));
        }
        let epu = self.elems_per_unit as usize;
        Ok(self.value.slice(start as usize * epu, len as usize * epu))
    }

    /// Copy the units [start, start+len) into `buf` without an
    /// intermediate allocation (the residency staging path: `buf` is
    /// arena-recycled and first-touched on the pinned worker, so the
    /// staged slice lands NUMA-local — DESIGN.md §2.12). Same contract as
    /// [`VectorArg::slice_units`], f32 Partition vectors only.
    pub fn fill_units(&self, start: u64, len: u64, buf: &mut Vec<f32>) -> Result<()> {
        if self.transfer != Transfer::Partition {
            return Err(Error::Spec(format!(
                "vector '{}' is COPY mode; cannot slice",
                self.name
            )));
        }
        let epu = self.elems_per_unit as usize;
        let all = self.value.as_f32()?;
        buf.extend_from_slice(&all[start as usize * epu..(start + len) as usize * epu]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units_respect_elems_per_unit() {
        let v = VectorArg::partitioned_f32("img", vec![0.0; 64 * 128], 128);
        assert_eq!(v.units(), 64);
    }

    #[test]
    fn slice_units_extracts_rows() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let v = VectorArg::partitioned_f32("m", data, 4);
        let s = v.slice_units(1, 2).unwrap();
        assert_eq!(s.as_f32().unwrap(), &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn copy_mode_rejects_slicing() {
        let v = VectorArg::copied_f32("all", vec![1.0; 8]);
        assert!(v.slice_units(0, 1).is_err());
        let mut buf = Vec::new();
        assert!(v.fill_units(0, 1, &mut buf).is_err());
    }

    #[test]
    fn fill_units_matches_slice_units() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let v = VectorArg::partitioned_f32("m", data, 4);
        let mut buf = Vec::new();
        v.fill_units(1, 2, &mut buf).unwrap();
        assert_eq!(buf.as_slice(), v.slice_units(1, 2).unwrap().as_f32().unwrap());
    }

    #[test]
    fn probe_distinguishes_interior_changes() {
        let a = ArgValue::F32((0..4096).map(|i| i as f32).collect());
        let mut data: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        data[2048] = -1.0; // same head/tail, different interior
        let b = ArgValue::F32(data);
        assert_ne!(a.probe(), b.probe());
        assert_eq!(a.probe(), a.probe());
    }

    #[test]
    fn same_contents_is_exact() {
        let a = ArgValue::F32(vec![1.0, 2.0, 3.0]);
        assert!(a.same_contents(&ArgValue::F32(vec![1.0, 2.0, 3.0])));
        assert!(!a.same_contents(&ArgValue::F32(vec![1.0, 2.0, 4.0])));
        assert!(!a.same_contents(&ArgValue::F32(vec![1.0, 2.0])));
        assert!(!a.same_contents(&ArgValue::I32(vec![1, 2, 3])));
    }

    #[test]
    fn merge_folds() {
        assert_eq!(Merge::Add.fold(2.0, 3.0), 5.0);
        assert_eq!(Merge::Mul.fold(2.0, 3.0), 6.0);
        assert_eq!(Merge::Sub.fold(2.0, 3.0), -1.0);
        assert_eq!(Merge::Div.fold(6.0, 3.0), 2.0);
    }
}
