//! Workload characterization (profile field (b) of Section 3.2.1).
//!
//! A workload is characterized by its number of dimensions, the number of
//! elements per dimension and whether it carries single- or double-precision
//! floating point data. The knowledge base interpolates over the feature
//! vector produced by [`Workload::features`].

use crate::util::json::Json;

/// Coarse behavioural class of a workload (ROADMAP item 4). Regular
/// data-parallel kernels have uniform per-chunk cost; the other classes
/// carry data-dependent cost the per-size interpolation cannot see, so the
/// KB keys profiles on the class and keeps a per-class cost model as the
/// interpolation fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum WorkloadClass {
    /// Uniform per-chunk cost (saxpy, filters, FFT, n-body).
    #[default]
    Regular,
    /// Sparse linear algebra: cost follows the nonzero distribution.
    Sparse,
    /// Graph traversal: cost follows frontier/degree structure.
    Traversal,
    /// Convergence/escape iteration: cost varies per element.
    Divergent,
}

impl WorkloadClass {
    pub fn label(&self) -> &'static str {
        match self {
            WorkloadClass::Regular => "regular",
            WorkloadClass::Sparse => "sparse",
            WorkloadClass::Traversal => "traversal",
            WorkloadClass::Divergent => "divergent",
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadClass> {
        match s {
            "regular" => Some(WorkloadClass::Regular),
            "sparse" => Some(WorkloadClass::Sparse),
            "traversal" => Some(WorkloadClass::Traversal),
            "divergent" => Some(WorkloadClass::Divergent),
            _ => None,
        }
    }
}

/// Characterization of one submitted workload.
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    /// Elements per dimension (len = dimensionality of the work space).
    pub dims: Vec<u64>,
    /// Double-precision data? (all paper benchmarks are single.)
    pub double_precision: bool,
    /// Behavioural class; non-Regular classes suffix [`Workload::id`] so
    /// the KB never conflates a sparse profile with a regular one of the
    /// same shape.
    pub class: WorkloadClass,
}

impl Workload {
    pub fn d1(n: u64) -> Workload {
        Workload {
            dims: vec![n],
            double_precision: false,
            class: WorkloadClass::Regular,
        }
    }

    pub fn d2(h: u64, w: u64) -> Workload {
        Workload {
            dims: vec![h, w],
            double_precision: false,
            class: WorkloadClass::Regular,
        }
    }

    pub fn d3(h: u64, w: u64, d: u64) -> Workload {
        Workload {
            dims: vec![h, w, d],
            double_precision: false,
            class: WorkloadClass::Regular,
        }
    }

    /// Builder: tag the workload with a behavioural class.
    pub fn with_class(mut self, class: WorkloadClass) -> Workload {
        self.class = class;
        self
    }

    /// Dimensionality of the computation's work space.
    pub fn dimensionality(&self) -> usize {
        self.dims.len()
    }

    /// Total number of elements.
    pub fn elems(&self) -> u64 {
        self.dims.iter().product()
    }

    /// Feature vector for interpolation. Dimensions are log2-scaled so that
    /// the RBF metric treats 1024→2048 and 4096→8192 as equally distant —
    /// workload behaviour is scale-multiplicative, not additive.
    pub fn features(&self) -> Vec<f64> {
        self.dims
            .iter()
            .map(|&d| (d.max(1) as f64).log2())
            .collect()
    }

    /// Stable identifier for KB keys, e.g. `2d:2048x2048:f32`. Non-Regular
    /// classes append a `:{class}` suffix so class-tagged profiles never
    /// alias the regular ones (and existing ids stay byte-stable).
    pub fn id(&self) -> String {
        let dims = self
            .dims
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let base = format!(
            "{}d:{}:{}",
            self.dims.len(),
            dims,
            if self.double_precision { "f64" } else { "f32" }
        );
        match self.class {
            WorkloadClass::Regular => base,
            c => format!("{base}:{}", c.label()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "dims",
                Json::arr(self.dims.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("double_precision", Json::Bool(self.double_precision)),
        ];
        // Only non-default classes are serialized, keeping existing KB
        // files byte-identical on round-trip.
        if self.class != WorkloadClass::Regular {
            fields.push(("class", Json::str(self.class.label())));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> crate::Result<Workload> {
        let dims = v
            .get("dims")?
            .as_arr()
            .ok_or_else(|| crate::Error::Kb("dims not array".into()))?
            .iter()
            .filter_map(|d| d.as_u64())
            .collect();
        Ok(Workload {
            dims,
            double_precision: v
                .get("double_precision")?
                .as_bool()
                .unwrap_or(false),
            class: v
                .get("class")
                .ok()
                .and_then(|c| c.as_str())
                .and_then(WorkloadClass::parse)
                .unwrap_or(WorkloadClass::Regular),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_distinguish_shape_and_precision() {
        assert_eq!(Workload::d2(2048, 1024).id(), "2d:2048x1024:f32");
        let mut w = Workload::d1(100);
        w.double_precision = true;
        assert_eq!(w.id(), "1d:100:f64");
    }

    #[test]
    fn features_are_log_scaled() {
        let f = Workload::d2(1024, 4096).features();
        assert_eq!(f, vec![10.0, 12.0]);
    }

    #[test]
    fn json_roundtrip() {
        let w = Workload::d3(32, 32, 512);
        let j = w.to_json();
        assert_eq!(Workload::from_json(&j).unwrap(), w);
    }

    #[test]
    fn elems_product() {
        assert_eq!(Workload::d3(4, 5, 6).elems(), 120);
    }

    #[test]
    fn class_suffixes_id_and_roundtrips() {
        let w = Workload::d1(4096).with_class(WorkloadClass::Sparse);
        assert_eq!(w.id(), "1d:4096:f32:sparse");
        assert_eq!(Workload::from_json(&w.to_json()).unwrap(), w);
        // Regular stays suffix-free and serializes no class field.
        let r = Workload::d1(4096);
        assert_eq!(r.id(), "1d:4096:f32");
        assert!(r.to_json().get("class").is_err());
        assert_eq!(Workload::from_json(&r.to_json()).unwrap(), r);
    }

    #[test]
    fn class_labels_roundtrip() {
        for c in [
            WorkloadClass::Regular,
            WorkloadClass::Sparse,
            WorkloadClass::Traversal,
            WorkloadClass::Divergent,
        ] {
            assert_eq!(WorkloadClass::parse(c.label()), Some(c));
        }
        assert_eq!(WorkloadClass::parse("spicy"), None);
    }
}
