//! Synthetic data generators for the five paper benchmarks.
//!
//! Everything is seeded through [`crate::util::rng::Rng`] so experiment runs
//! are reproducible bit-for-bit.

use crate::util::rng::Rng;

/// Gray-scale image in [0, 255], row-major `h*w` f32.
pub fn image(seed: u64, h: usize, w: usize) -> Vec<f32> {
    // Smooth gradient + seeded speckle: cheap but non-trivial content so
    // filters act on realistic value distributions.
    let mut rng = Rng::new(seed);
    let mut img = Vec::with_capacity(h * w);
    for r in 0..h {
        for c in 0..w {
            let base = 127.0
                + 80.0 * ((r as f32 / h.max(1) as f32) * std::f32::consts::PI).sin()
                + 40.0 * ((c as f32 / w.max(1) as f32) * 2.0 * std::f32::consts::PI).cos();
            let speckle = (rng.f32() - 0.5) * 30.0;
            img.push((base + speckle).clamp(0.0, 255.0));
        }
    }
    img
}

/// 3-D volume in [0, 255], `h*w*d` f32 (x-major like the kernels expect).
pub fn volume(seed: u64, h: usize, w: usize, d: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x5e6);
    (0..h * w * d).map(|_| rng.f32() * 255.0).collect()
}

/// Random float vector with N(0, 1) entries.
pub fn randn_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Body set for NBody: `n` rows of (x, y, z, m), positions in a unit cube,
/// masses in [0.5, 2).
pub fn bodies(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n * 4);
    for _ in 0..n {
        out.push(rng.range_f64(-1.0, 1.0) as f32);
        out.push(rng.range_f64(-1.0, 1.0) as f32);
        out.push(rng.range_f64(-1.0, 1.0) as f32);
        out.push(rng.range_f64(0.5, 2.0) as f32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_in_range_and_deterministic() {
        let a = image(1, 16, 32);
        let b = image(1, 16, 32);
        assert_eq!(a, b);
        assert_eq!(a.len(), 512);
        assert!(a.iter().all(|&v| (0.0..=255.0).contains(&v)));
    }

    #[test]
    fn bodies_layout() {
        let b = bodies(2, 8);
        assert_eq!(b.len(), 32);
        for i in 0..8 {
            assert!(b[i * 4 + 3] >= 0.5 && b[i * 4 + 3] < 2.0);
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(randn_vec(1, 16), randn_vec(2, 16));
    }
}
