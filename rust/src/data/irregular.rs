//! Deterministic generators for the irregular-workload tier (ROADMAP
//! item 4): ELL-padded sparse matrices with skewed row lengths, padded
//! adjacency lists with skewed degrees, and Mandelbrot coordinate planes.
//!
//! All generators are pure functions of their (seed, index) inputs — the
//! CLI, the propcheck suite and the benches synthesize bit-identical
//! buffers without sharing state, and chunk decomposition can never
//! change the data a row/node/pixel sees.

/// splitmix64 avalanche step: uncorrelated 64-bit streams from
/// (seed, index) pairs.
fn mix(seed: u64, index: u64) -> u64 {
    let mut z = seed
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(index)
        .wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Uniform f64 in [0, 1) from one hash draw.
fn unit(seed: u64, index: u64) -> f64 {
    (mix(seed, index) >> 11) as f64 / (1u64 << 53) as f64
}

/// Skewed trip count in [1, max]: squaring the uniform draw biases mass
/// toward short rows with a heavy tail of long ones — the row-length
/// shape SpMV schedulers actually face.
pub fn skewed_len(seed: u64, index: u64, max: usize) -> usize {
    let u = unit(seed, index);
    1 + (u * u * (max as f64)) as usize % max
}

/// ELL-padded sparse operand set: `(cols, vals, x)` for `rows` rows with
/// up to `k_pad` nonzeros each against a dense vector of `n_cols`
/// entries. Column indices are stored as exact f32 integers, -1.0-padded
/// past each row's length.
pub fn spmv_inputs(seed: u64, rows: usize, k_pad: usize, n_cols: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut cols = vec![-1.0f32; rows * k_pad];
    let mut vals = vec![0.0f32; rows * k_pad];
    for r in 0..rows {
        let len = skewed_len(seed, r as u64, k_pad);
        for k in 0..len {
            let draw = mix(seed ^ 0x5b_ff, (r * k_pad + k) as u64);
            cols[r * k_pad + k] = (draw % n_cols as u64) as f32;
            vals[r * k_pad + k] = (unit(seed ^ 0xa1, (r * k_pad + k) as u64) * 2.0 - 1.0) as f32;
        }
    }
    let x: Vec<f32> = (0..n_cols)
        .map(|i| (unit(seed ^ 0x77, i as u64) * 2.0 - 1.0) as f32)
        .collect();
    (cols, vals, x)
}

/// Padded adjacency + frontier flags: `(adj, frontier)` for `nodes`
/// nodes with up to `deg_pad` neighbours each out of `n_nodes`, and a
/// sparse 0/1 frontier (~1 node in 7).
pub fn bfs_inputs(seed: u64, nodes: usize, deg_pad: usize, n_nodes: usize) -> (Vec<f32>, Vec<f32>) {
    let mut adj = vec![-1.0f32; nodes * deg_pad];
    for v in 0..nodes {
        let deg = skewed_len(seed ^ 0x13, v as u64, deg_pad);
        for d in 0..deg {
            let draw = mix(seed ^ 0x2c_e1, (v * deg_pad + d) as u64);
            adj[v * deg_pad + d] = (draw % n_nodes as u64) as f32;
        }
    }
    let frontier: Vec<f32> = (0..n_nodes)
        .map(|i| if mix(seed ^ 0x9d, i as u64) % 7 == 0 { 1.0 } else { 0.0 })
        .collect();
    (adj, frontier)
}

/// Mandelbrot coordinate plane: `px` points scanning the classic
/// [-2.5, 1] x [-1.25, 1.25] window row-major over a near-square grid, so
/// escape-iteration cost varies smoothly but drastically across chunks.
pub fn mandelbrot_plane(px: usize) -> (Vec<f32>, Vec<f32>) {
    let w = (px as f64).sqrt().ceil() as usize;
    let mut re = Vec::with_capacity(px);
    let mut im = Vec::with_capacity(px);
    for i in 0..px {
        let (x, y) = (i % w, i / w);
        re.push((-2.5 + 3.5 * x as f64 / w as f64) as f32);
        im.push((-1.25 + 2.5 * y as f64 / w as f64) as f32);
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(spmv_inputs(42, 64, 16, 256), spmv_inputs(42, 64, 16, 256));
        assert_eq!(bfs_inputs(42, 64, 8, 256), bfs_inputs(42, 64, 8, 256));
        assert_eq!(mandelbrot_plane(4096), mandelbrot_plane(4096));
        assert_ne!(spmv_inputs(42, 64, 16, 256), spmv_inputs(43, 64, 16, 256));
    }

    #[test]
    fn row_lengths_are_skewed_and_bounded() {
        let lens: Vec<usize> = (0..4096).map(|r| skewed_len(7, r, 16)).collect();
        assert!(lens.iter().all(|&l| (1..=16).contains(&l)));
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        // Squared-uniform draw: mean well below the midpoint, tail present.
        assert!(mean < 8.0, "mean {mean} not skewed short");
        assert!(lens.iter().any(|&l| l >= 14), "no long-row tail");
    }

    #[test]
    fn sparse_indices_stay_in_range() {
        let (cols, vals, x) = spmv_inputs(3, 128, 16, 512);
        assert_eq!(x.len(), 512);
        for (&c, &v) in cols.iter().zip(&vals) {
            if c >= 0.0 {
                assert!((c as usize) < 512);
                assert!(c == c.trunc(), "column index must be an exact f32 int");
            } else {
                assert_eq!(v, 0.0, "padding carries zero values");
            }
        }
        let (adj, frontier) = bfs_inputs(3, 128, 8, 512);
        assert!(adj.iter().all(|&a| a < 512.0));
        assert!(frontier.iter().any(|&f| f > 0.0));
    }
}
