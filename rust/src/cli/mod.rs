//! Command-line argument parser (clap is unavailable offline).
//!
//! Supports `command [--flag value] [--switch] positional...` with typed
//! accessors and generated usage text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: the first non-flag token is the command; `--k v`
    /// pairs are flags; `--k` followed by another `--` token (or nothing)
    /// is a switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), tokens[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                if out.command.is_none() {
                    out.command = Some(t.clone());
                } else {
                    out.positional.push(t.clone());
                }
                i += 1;
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_flags_switches() {
        let a = parse("run --bench saxpy --n 1000000 extra --verbose");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("bench"), Some("saxpy"));
        assert_eq!(a.get_u64("n", 0).unwrap(), 1_000_000);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn adjacent_switches() {
        let a = parse("x --a --b --c v");
        assert!(a.has("a") && a.has("b"));
        assert_eq!(a.get("c"), Some("v"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n notanumber");
        assert!(a.get_u64("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_u64("n", 42).unwrap(), 42);
        assert_eq!(a.get_or("mode", "sim"), "sim");
    }
}
