//! Integration tests for the adaptation pipeline over the simulated
//! machine: tuner -> KB -> derivation -> load balancer, end-to-end
//! (the Section 3.2/3.3 workflow of Fig 4).

use marrow::balance::LoadBalancer;
use marrow::bench::workloads;
use marrow::data::workload::Workload;
use marrow::kb::KnowledgeBase;
use marrow::platform::device::{i7_hd7950, opteron_6272_quad};
use marrow::scheduler::{ExecEnv, SimEnv};
use marrow::sim::cpuload::LoadProfile;
use marrow::sim::machine::SimMachine;
use marrow::tuner::builder::{build_profile, TunerOpts};
use marrow::tuner::profile::ProfileOrigin;

#[test]
fn fig4_workflow_build_store_derive_balance() {
    // 1. New (SCT, workload) arrives; profile construction runs (box
    //    "Build SCT profile") and the result is persisted.
    let b1 = workloads::filter_pipeline(1024, 1024, true);
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 1));
    env.copy_bytes = b1.copy_bytes;
    let p1 = build_profile(&mut env, &b1.sct, &b1.workload, b1.total_units, &TunerOpts::default())
        .unwrap();
    let mut kb = KnowledgeBase::in_memory();
    kb.store(p1.clone());

    // 2. A different workload of the same SCT arrives: derivation (box
    //    "Derive work distribution") must produce a nearby configuration.
    let b2 = workloads::filter_pipeline(2048, 2048, true);
    let derived = kb.derive(&b2.sct.id(), &b2.workload).expect("derivable");
    assert!((derived.cpu_share - p1.config.cpu_share).abs() < 0.3);

    // 3. Recurrent executions under the derived config are monitored; the
    //    balancer refines and the refined profile is persisted.
    let mut cfg = derived;
    let mut lb = LoadBalancer::new(0.85, cfg.cpu_share);
    let mut env2 = SimEnv::new(SimMachine::new(i7_hd7950(1), 2));
    env2.copy_bytes = b2.copy_bytes;
    let mut total = 0.0;
    for _ in 0..50 {
        total += lb
            .step(&mut env2, &b2.sct, b2.total_units, &mut cfg)
            .unwrap()
            .total;
    }
    kb.store(marrow::tuner::profile::Profile {
        sct_id: b2.sct.id(),
        workload: b2.workload.clone(),
        config: cfg,
        best_time: total / 50.0,
        origin: ProfileOrigin::Refined,
    });
    assert_eq!(kb.len(), 2);
    // The refined entry is retrievable verbatim.
    assert!(kb.lookup(&b2.sct.id(), &b2.workload).is_some());
}

#[test]
fn derived_config_performs_close_to_built() {
    // The Table-5 claim in miniature: derive for an unseen size and compare
    // against a from-scratch construction.
    let train = [(1024u64, 1024u64), (4096, 4096)];
    let mut kb = KnowledgeBase::in_memory();
    for (i, &(h, w)) in train.iter().enumerate() {
        let b = workloads::filter_pipeline(h, w, true);
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 10 + i as u64));
        env.copy_bytes = b.copy_bytes;
        let p = build_profile(&mut env, &b.sct, &b.workload, b.total_units, &TunerOpts::default())
            .unwrap();
        kb.store(p);
    }
    let b = workloads::filter_pipeline(2048, 2048, true);
    let derived = kb.derive(&b.sct.id(), &b.workload).unwrap();

    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 20));
    env.copy_bytes = b.copy_bytes;
    let built =
        build_profile(&mut env, &b.sct, &b.workload, b.total_units, &TunerOpts::default())
            .unwrap();

    let t_derived = env.execute(&b.sct, b.total_units, &derived).unwrap().total;
    let t_built = env.execute(&b.sct, b.total_units, &built.config).unwrap().total;
    // Paper: performance error below ~5% after a few images; allow slack
    // for the coarser two-point training set.
    assert!(
        t_derived < t_built * 1.25,
        "derived {t_derived} vs built {t_built}"
    );
}

#[test]
fn load_spike_and_recovery_round_trip() {
    // Load appears, balancer shifts to GPU; load disappears, balancer
    // shifts back towards the CPU.
    let b = workloads::saxpy(10_000_000);
    let sim = SimMachine::new(i7_hd7950(1), 33)
        .with_load(LoadProfile::new(vec![(0, 0), (20, 10), (90, 0)]));
    let mut env = SimEnv::new(sim);
    env.copy_bytes = b.copy_bytes;

    let mut env0 = SimEnv::new(SimMachine::new(i7_hd7950(1), 34));
    env0.copy_bytes = b.copy_bytes;
    let p = build_profile(&mut env0, &b.sct, &b.workload, b.total_units, &TunerOpts::default())
        .unwrap();
    let mut cfg = p.config.clone();
    let steady = cfg.cpu_share;
    assert!(steady > 0.1, "saxpy should use the CPU: {steady}");

    let mut lb = LoadBalancer::new(0.85, steady);
    let mut share_under_load = steady;
    for run in 0..160u64 {
        lb.step(&mut env, &b.sct, b.total_units, &mut cfg).unwrap();
        if run == 85 {
            share_under_load = cfg.cpu_share;
        }
    }
    assert!(
        share_under_load < steady,
        "under load share must drop: {share_under_load} vs {steady}"
    );
    assert!(
        cfg.cpu_share > share_under_load,
        "after recovery share must rebound: {} vs {share_under_load}",
        cfg.cpu_share
    );
}

#[test]
fn cpu_only_machine_full_flow() {
    let b = workloads::fft(128);
    let mut env = SimEnv::new(SimMachine::new(opteron_6272_quad(), 44));
    env.copy_bytes = b.copy_bytes;
    let p = build_profile(&mut env, &b.sct, &b.workload, b.total_units, &TunerOpts::default())
        .unwrap();
    assert_eq!(p.config.cpu_share, 1.0);
    assert!(p.config.overlap.is_empty());
    let mut kb = KnowledgeBase::in_memory();
    kb.store(p);
    let derived = kb.derive(&b.sct.id(), &Workload::d1(256 * 1024 * 1024)).unwrap();
    assert_eq!(derived.cpu_share, 1.0);
}
