//! Integration tests for the buffer-residency layer (DESIGN.md §2.6) in
//! the stub build: the simulated backend books the same upload / reuse /
//! migration accounting the real runner's pool produces, so every
//! acceptance property is observable without PJRT.

use marrow::bench::workloads;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::{ExecEnv, SimEnv};
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, ExecProfile, Session};
use marrow::sim::machine::SimMachine;
use marrow::tuner::profile::FrameworkConfig;

fn cfg(share: f64) -> FrameworkConfig {
    FrameworkConfig {
        fission: marrow::platform::cpu::FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: share,
    }
}

#[test]
fn pipeline_workload_reports_uploads_avoided() {
    // A 3-stage filter pipeline: stages 2 and 3 read the previous stage's
    // output in place — a device-resident runtime re-uploads nothing
    // between stages.
    let b = workloads::filter_pipeline(2048, 2048, false);
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 7));
    let out = env
        .run_request(&b.sct, &RequestArgs::default(), b.total_units, &cfg(0.25))
        .unwrap();
    assert!(
        out.exec.transfers.uploads_avoided > 0,
        "pipeline stages must reuse resident intermediates: {:?}",
        out.exec.transfers
    );
    assert!(out.exec.transfers.bytes_uploaded > 0, "cold inputs upload");
}

#[test]
fn loop_workload_reports_uploads_avoided() {
    // NBody: a global-sync Loop — the partition inputs upload once and
    // every later iteration reuses them (only the COPY state re-ships).
    let b = workloads::nbody(4096, 10);
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 9));
    env.set_copy_bytes(b.copy_bytes);
    let out = env
        .run_request(&b.sct, &RequestArgs::default(), b.total_units, &cfg(0.0))
        .unwrap();
    assert!(
        out.exec.transfers.uploads_avoided > 0,
        "loop iterations must reuse resident inputs: {:?}",
        out.exec.transfers
    );
}

#[test]
fn second_request_uploads_strictly_fewer_bytes() {
    // Repeated Session::run over the same workload: the first request
    // uploads the partition inputs, the second finds them resident.
    let comp = Computation::from(workloads::filter_pipeline(2048, 2048, false));
    let s = Session::simulated(i7_hd7950(1), 21);
    let first = s.run(&comp, &RequestArgs::default()).unwrap();
    let second = s.run(&comp, &RequestArgs::default()).unwrap();
    assert!(first.exec.transfers.bytes_uploaded > 0);
    assert!(
        second.exec.transfers.bytes_uploaded < first.exec.transfers.bytes_uploaded,
        "second request must upload strictly fewer bytes ({} vs {})",
        second.exec.transfers.bytes_uploaded,
        first.exec.transfers.bytes_uploaded
    );
    assert!(second.exec.transfers.uploads_avoided > 0);
    // The session's aggregate counters carry the layer's totals.
    let st = s.stats();
    assert!(st.uploads_avoided > 0);
    assert!(st.bytes_uploaded >= first.exec.transfers.bytes_uploaded);
}

#[test]
fn residency_discount_speeds_up_warm_requests() {
    // The cost model charges the upload half of the PCIe traffic only
    // while the inputs are cold: with identical noise seeds, a warm
    // GPU-heavy request must price at or below the cold one.
    let b = workloads::saxpy(1 << 22);
    let comp = Computation::from(b);
    let cold = {
        let s = Session::simulated(i7_hd7950(1), 33);
        let out = s
            .run_with(
                &comp,
                &RequestArgs::default(),
                marrow::session::ConfigOverride::new().gpu_only(),
            )
            .unwrap();
        out.exec.gpu_time
    };
    let warm = {
        let s = Session::simulated(i7_hd7950(1), 33);
        s.run_with(
            &comp,
            &RequestArgs::default(),
            marrow::session::ConfigOverride::new().gpu_only(),
        )
        .unwrap();
        let out = s
            .run_with(
                &comp,
                &RequestArgs::default(),
                marrow::session::ConfigOverride::new().gpu_only(),
            )
            .unwrap();
        out.exec.gpu_time
    };
    // Warm ran as the *second* request of its session (different noise
    // draw), so compare with slack: the transfer discount dominates the
    // ~1% lognormal noise for a PCIe-bound saxpy.
    assert!(
        warm < cold * 1.02,
        "warm request must not price above cold + noise: warm {warm} cold {cold}"
    );
}

#[test]
fn disabling_residency_restores_per_request_uploads() {
    let comp = Computation::from(workloads::filter_pipeline(1024, 1024, false));
    let s = Session::simulated(i7_hd7950(1), 5);
    s.set_residency_enabled(false);
    let first = s.run(&comp, &RequestArgs::default()).unwrap();
    let second = s.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(second.exec.transfers.uploads_avoided, 0);
    assert_eq!(
        second.exec.transfers.bytes_uploaded,
        first.exec.transfers.bytes_uploaded,
        "without residency every request re-uploads the same bytes"
    );
}

#[test]
fn transfer_accounting_is_conserved_across_drain_modes_and_depths() {
    // The conservation invariant (DESIGN.md §2.12): for a fixed request,
    // bytes_uploaded + uploads_avoided_bytes + uploads_overlapped_bytes
    // is a property of the workload — drain mode and prefetch depth only
    // move bytes between the buckets, never create or destroy them.
    use marrow::scheduler::DrainMode;
    let b = workloads::filter_pipeline(1 << 15, 1 << 15, false);
    let mut baseline: Option<u64> = None;
    for mode in [DrainMode::Dataflow, DrainMode::Barrier] {
        for depth in [0u32, 1, 2, 8] {
            let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 13));
            env.set_drain_mode(mode);
            env.set_prefetch_depth(depth);
            let out = env
                .run_request(&b.sct, &RequestArgs::default(), b.total_units, &cfg(0.25))
                .unwrap();
            let t = out.exec.transfers;
            let sum = t.accounted_upload_bytes();
            assert_eq!(
                sum,
                t.bytes_uploaded + t.uploads_avoided_bytes + t.uploads_overlapped_bytes
            );
            match baseline {
                None => baseline = Some(sum),
                Some(base) => assert_eq!(
                    sum, base,
                    "accounted upload bytes must not depend on \
                     {mode:?}/depth {depth}: {t:?}"
                ),
            }
        }
    }
}

#[test]
fn prefetch_depth_books_overlapped_uploads_in_sim() {
    // With a dataflow drain and lookahead, part of the cold upload hides
    // under compute: booked as overlapped, surfaced as overlap% > 0.
    let b = workloads::filter_pipeline(1 << 15, 1 << 15, false);
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 17));
    env.set_prefetch_depth(4);
    let out = env
        .run_request(&b.sct, &RequestArgs::default(), b.total_units, &cfg(0.25))
        .unwrap();
    let t = out.exec.transfers;
    assert!(
        t.uploads_overlapped > 0 && t.uploads_overlapped_bytes > 0,
        "prefetch must hide some of the cold upload: {t:?}"
    );
    assert!(t.bytes_uploaded > 0, "the first chunk's upload stays exposed");
}

#[test]
fn prefetch_overlap_lowers_dataflow_makespan_in_sim() {
    // Hidden upload leaves the critical path: with identical noise seeds
    // the prefetch-on virtual makespan prices strictly below prefetch-off
    // on a transfer-heavy workload.
    let b = workloads::filter_pipeline(1 << 15, 1 << 15, false);
    let run = |depth: u32| {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 19));
        env.set_prefetch_depth(depth);
        env.run_request(&b.sct, &RequestArgs::default(), b.total_units, &cfg(0.25))
            .unwrap()
            .exec
            .total
    };
    let off = run(0);
    let on = run(4);
    assert!(
        on < off,
        "prefetch-on makespan must beat prefetch-off: on {on} off {off}"
    );
}

#[test]
fn pool_of_sessions_reports_transfer_stats_in_serve_report() {
    let pool = SessionPool::build(2, |i| Session::simulated(i7_hd7950(1), 50 + i as u64));
    let reqs: Vec<ServeRequest> = (0..6)
        .map(|_| {
            ServeRequest::from(Computation::from(workloads::filter_pipeline(
                1024, 1024, false,
            )))
        })
        .collect();
    let report = pool
        .serve(
            &reqs,
            &ServeOpts {
                concurrency: 2,
                exec: ExecProfile::new().tasks_per_slot(8),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(report.completed, 6);
    assert!(report.stats.uploads_avoided > 0);
    assert!(report.stats.bytes_uploaded > 0);
    let line = report.summary();
    assert!(line.contains("uploads avoided"), "summary: {line}");
}
