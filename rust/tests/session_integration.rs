//! Integration tests for the `Session` facade: the Section 3.2.3 config
//! resolution chain (KB hit -> RBF derivation -> cold-start profile build),
//! outcome feedback into the knowledge base, and adaptive rebalancing of
//! repeated requests — all against the simulated backend.

use marrow::bench::workloads;
use marrow::data::workload::Workload;
use marrow::kb::{mk_profile, KnowledgeBase};
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::session::{Computation, ConfigOrigin, Session};
use marrow::tuner::profile::ProfileOrigin;

#[test]
fn kb_hit_resolution_uses_stored_profile() {
    let comp = Computation::from(workloads::saxpy(1 << 22));
    let mut kb = KnowledgeBase::in_memory();
    kb.store(mk_profile(
        &comp.sct_id(),
        Workload::d1(1 << 22),
        FissionLevel::L2,
        vec![4],
        0.3,
        1.0,
    ));
    let s = Session::simulated(i7_hd7950(1), 1).with_kb(kb);
    let out = s.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(out.origin, ConfigOrigin::KbHit);
    assert!((out.config.cpu_share - 0.3).abs() < 1e-12);
    assert_eq!(s.stats().kb_hits, 1);
}

#[test]
fn rbf_derivation_interpolates_between_stored_sizes() {
    let comp = Computation::from(workloads::saxpy(1 << 22));
    let id = comp.sct_id();
    let mut kb = KnowledgeBase::in_memory();
    kb.store(mk_profile(&id, Workload::d1(1 << 20), FissionLevel::L2, vec![4], 0.10, 1.0));
    kb.store(mk_profile(&id, Workload::d1(1 << 24), FissionLevel::L2, vec![4], 0.30, 1.0));
    let s = Session::simulated(i7_hd7950(1), 2).with_kb(kb);
    let out = s.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(out.origin, ConfigOrigin::Derived);
    assert!(
        out.config.cpu_share > 0.10 && out.config.cpu_share < 0.30,
        "share {}",
        out.config.cpu_share
    );
    // The derived outcome is fed back: the next request is an exact hit.
    {
        let kb = s.kb();
        let p = kb.lookup(&id, &Workload::d1(1 << 22)).expect("stored");
        assert_eq!(p.origin, ProfileOrigin::Derived);
    }
    let again = s.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(again.origin, ConfigOrigin::KbHit);
}

#[test]
fn cold_start_builds_profile_and_caches_it() {
    // Same machine/workload/seed regime as the tuner's own hybrid test, so
    // the expected distribution band is already validated there.
    let comp = Computation::from(workloads::saxpy(1 << 24));
    let s = Session::simulated(i7_hd7950(1), 9);
    assert!(s.kb().is_empty());
    let out = s.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(out.origin, ConfigOrigin::Built);
    assert_eq!(s.kb().len(), 1);
    // Streaming workload on the hybrid box: the built profile must be a
    // genuine hybrid distribution, not the baseline.
    assert!(out.config.cpu_share > 0.02 && out.config.cpu_share < 0.6);
    let again = s.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(again.origin, ConfigOrigin::KbHit);
    assert_eq!(s.stats().built, 1);
    assert_eq!(s.stats().kb_hits, 1);
}

#[test]
fn repeated_runs_converge_cpu_share_via_balancer() {
    // Acceptance: seed the KB with a badly unbalanced split (85% CPU for a
    // GPU-favoured streaming kernel) and let repeated Session::run calls
    // converge cpu_share through the monitor + adaptive binary search.
    let comp = Computation::from(workloads::saxpy(1 << 22));
    let mut kb = KnowledgeBase::in_memory();
    kb.store(mk_profile(
        &comp.sct_id(),
        Workload::d1(1 << 22),
        FissionLevel::L2,
        vec![4],
        0.85,
        1.0,
    ));
    let s = Session::simulated(i7_hd7950(1), 7).with_kb(kb);

    let args = RequestArgs::default();
    let first = s.run(&comp, &args).unwrap();
    assert!((first.config.cpu_share - 0.85).abs() < 1e-12);
    let t_first = first.exec.total;

    let mut shares = vec![first.config.cpu_share];
    let mut last = first;
    for _ in 0..59 {
        last = s.run(&comp, &args).unwrap();
        shares.push(last.config.cpu_share);
    }

    assert!(
        s.stats().balance_ops >= 2,
        "balancer must trigger: {:?}",
        s.stats()
    );
    let final_share = last.config.cpu_share;
    assert!(
        final_share < 0.6,
        "cpu_share must move off the bad split: trace {shares:?}"
    );
    // The search settles: the last third of the trace stays in a narrow
    // band instead of ping-ponging across the interval.
    let tail = &shares[shares.len() - 20..];
    let (lo, hi) = tail
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &s| {
            (l.min(s), h.max(s))
        });
    assert!(hi - lo < 0.35, "share must settle, trace {shares:?}");
    assert!(hi < 0.6, "settled band must be near the optimum: {shares:?}");
    // Performance must improve once the share has converged.
    assert!(
        last.exec.total < t_first,
        "converged runs must beat the unbalanced start: {} vs {t_first}",
        last.exec.total
    );
    // The refined distribution is persisted for future sessions.
    let kb = s.kb();
    let p = kb
        .lookup(&comp.sct_id(), &Workload::d1(1 << 22))
        .expect("profile kept");
    assert_eq!(p.origin, ProfileOrigin::Refined);
    assert!(p.config.cpu_share < 0.6);
}

#[test]
fn session_kb_persists_across_sessions() {
    let path = std::env::temp_dir().join("marrow_session_kb_test.json");
    let _ = std::fs::remove_file(&path);
    let comp = Computation::from(workloads::saxpy(1 << 20));
    {
        let s = Session::simulated(i7_hd7950(1), 5)
            .with_kb_path(&path)
            .unwrap();
        let out = s.run(&comp, &RequestArgs::default()).unwrap();
        assert_eq!(out.origin, ConfigOrigin::Built);
        s.save_kb().unwrap();
    }
    {
        let s = Session::simulated(i7_hd7950(1), 6)
            .with_kb_path(&path)
            .unwrap();
        let out = s.run(&comp, &RequestArgs::default()).unwrap();
        assert_eq!(out.origin, ConfigOrigin::KbHit, "warm start across sessions");
    }
    let _ = std::fs::remove_file(&path);
}
