//! Durable KB store integration (DESIGN.md §2.9): write-through
//! persistence across sessions, concurrent multi-store flushing into one
//! directory, snapshot warm-start end-to-end through `Session::run`, and
//! property tests over the snapshot merge (idempotent, commutative,
//! never-worse).

use std::path::PathBuf;

use marrow::bench::workloads;
use marrow::data::workload::Workload;
use marrow::kb::store::snapshot::KbSnapshot;
use marrow::kb::store::{machine_digest, KbStore, StoreRecord};
use marrow::kb::{mk_profile, KnowledgeBase};
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::SimEnv;
use marrow::session::{Computation, ConfigOrigin, Session};
use marrow::sim::machine::SimMachine;
use marrow::tuner::profile::ProfileOrigin;
use marrow::util::propcheck::forall;

fn quiet_session(seed: u64) -> Session<SimEnv> {
    Session::sim(SimMachine::quiet(i7_hd7950(1), seed))
}

/// Fresh temp dir per test (removed up front so reruns start clean).
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "marrow_kbstore_it_{name}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The digest every `SimEnv` session reports for `i7_hd7950(1)`.
fn sim_digest() -> String {
    machine_digest("analytic", &i7_hd7950(1))
}

#[test]
fn write_through_persists_profiles_across_sessions() {
    let dir = tmp("writethrough");
    let comp = Computation::from(workloads::saxpy(1 << 20));
    {
        let session = quiet_session(1).with_kb_store(&dir).unwrap();
        let out = session.run(&comp, &RequestArgs::default()).unwrap();
        assert_eq!(out.origin, ConfigOrigin::Built);
        let st = session.stats();
        assert_eq!(st.built, 1);
        assert!(st.build_secs > 0.0, "Algorithm 1 wall time untracked");
        session.save_kb().unwrap();
    }
    // A brand-new session over the same store resolves the same
    // computation as an exact hit — and knows it came from the store.
    let session = quiet_session(2).with_kb_store(&dir).unwrap();
    let out = session.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(out.origin, ConfigOrigin::KbHit);
    let st = session.stats();
    assert_eq!(st.built, 0, "warm store must skip Algorithm 1");
    assert_eq!(st.warm_hits, 1, "store hit not counted as warm");
    assert_eq!(st.build_secs, 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_stores_on_one_directory_lose_nothing() {
    let dir = tmp("concurrent");
    std::fs::create_dir_all(&dir).unwrap();
    const PER_THREAD: usize = 20;
    std::thread::scope(|scope| {
        for t in 0..2u64 {
            let dir = &dir;
            scope.spawn(move || {
                let mut store = KbStore::open(dir, "m-conc").unwrap();
                for i in 0..PER_THREAD {
                    store.stage(
                        mk_profile(
                            &format!("sct_t{t}_{i}"),
                            Workload::d1(1 << 20),
                            FissionLevel::L2,
                            vec![4],
                            0.5,
                            1e-3,
                        ),
                        None,
                    );
                    // Interleaved flushes: each thread commits segments
                    // while the other is mid-stream.
                    if (i + 1) % 5 == 0 {
                        store.flush().unwrap();
                    }
                }
                store.flush().unwrap();
            });
        }
    });
    let store = KbStore::open(&dir, "m-conc").unwrap();
    assert_eq!(
        store.len(),
        2 * PER_THREAD,
        "interleaved flushes dropped records"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_serve_skips_cold_builds_entirely() {
    let dir_a = tmp("warmstart_a");
    let dir_b = tmp("warmstart_b");
    let comp = Computation::from(workloads::saxpy(1 << 20));
    // Cold fleet member: builds once, persists into store A.
    let cold = quiet_session(3).with_kb_store(&dir_a).unwrap();
    cold.run(&comp, &RequestArgs::default()).unwrap();
    cold.save_kb().unwrap();
    assert!(cold.stats().build_secs > 0.0);
    // Export A, import into a fresh member backed by empty store B.
    let snap = KbSnapshot::from_store(&KbStore::open(&dir_a, &sim_digest()).unwrap());
    assert_eq!(snap.len(), 1);
    let warm = quiet_session(4).with_kb_store(&dir_b).unwrap();
    let (exact, hints) = warm.import_kb_snapshot(&snap);
    assert_eq!((exact, hints), (1, 0));
    let out = warm.run(&comp, &RequestArgs::default()).unwrap();
    assert_eq!(out.origin, ConfigOrigin::KbHit);
    let st = warm.stats();
    assert_eq!(st.built, 0, "warm-started member ran Algorithm 1");
    assert_eq!(st.warm_hits, 1);
    assert_eq!(st.build_secs, 0.0);
    // Idempotent: importing the same snapshot again changes nothing.
    assert_eq!(warm.import_kb_snapshot(&snap), (0, 0));
    for d in [&dir_a, &dir_b] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn mismatched_manifest_snapshot_feeds_derivation_only() {
    let dir = tmp("foreign");
    let comp = Computation::from(workloads::saxpy(1 << 20));
    let (sct, w, _) = comp.spec().unwrap();
    // A snapshot recorded on some other machine: same computation, but a
    // digest this platform does not match.
    let snap = KbSnapshot::from_records([StoreRecord::new(
        mk_profile(&sct.id(), w.clone(), FissionLevel::L2, vec![4], 0.4, 1e-3),
        "some-other-machine",
    )]);
    let session = quiet_session(5).with_kb_store(&dir).unwrap();
    assert_eq!(session.import_kb_snapshot(&snap), (0, 1));
    let out = session.run(&comp, &RequestArgs::default()).unwrap();
    // The foreign profile is never an exact hit, but its configuration
    // seeds derivation — so no cold build, no warm hit.
    assert_eq!(out.origin, ConfigOrigin::Derived);
    let st = session.stats();
    assert_eq!(st.built, 0);
    assert_eq!(st.warm_hits, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn epoch_change_syncs_between_live_knowledge_bases() {
    let dir = tmp("epochs");
    let mut kb1 = KnowledgeBase::open_store(&dir, "m-epoch").unwrap();
    let mut kb2 = KnowledgeBase::open_store(&dir, "m-epoch").unwrap();
    kb1.store(mk_profile(
        "sct_a",
        Workload::d1(1 << 20),
        FissionLevel::L2,
        vec![4],
        0.5,
        1e-3,
    ));
    kb1.save().unwrap();
    kb2.store(mk_profile(
        "sct_b",
        Workload::d1(1 << 21),
        FissionLevel::L2,
        vec![4],
        0.5,
        2e-3,
    ));
    // kb2's sync commits its own record and absorbs kb1's flush.
    kb2.save().unwrap();
    assert_eq!(kb2.len(), 2, "kb2 missed kb1's segment");
    assert!(kb2.lookup("sct_a", &Workload::d1(1 << 20)).is_some());
    // And the reverse direction on kb1's next sync.
    assert!(kb1.sync_store().unwrap() > 0);
    assert_eq!(kb1.len(), 2, "kb1 missed kb2's segment");
    // Compaction keeps the merged view intact.
    let mut store = KbStore::open(&dir, "m-epoch").unwrap();
    let (live, removed) = store.gc().unwrap();
    assert_eq!(live, 2);
    assert!(removed >= 2);
    assert_eq!(KbStore::open(&dir, "m-epoch").unwrap().len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}

// --- merge property tests -------------------------------------------------

/// Decode one generated tuple into a store record: a handful of (SCT,
/// workload) pairs so collisions are common, three origins, positive
/// times. The digest is fixed — merge semantics are per content key.
fn decode_record(v: &(u64, u64, f64)) -> StoreRecord {
    let sct = format!("sct{}", v.0 % 3);
    let wl = Workload::d1(1 << (10 + (v.1 % 4) as u32));
    let mut p = mk_profile(&sct, wl, FissionLevel::L2, vec![4], 0.5, 1e-5 + v.2.abs());
    p.origin = match v.0 % 5 {
        0 => ProfileOrigin::Derived,
        1 | 2 => ProfileOrigin::Built,
        _ => ProfileOrigin::Refined,
    };
    StoreRecord::new(p, "m-prop")
}

fn gen_records(r: &mut marrow::util::rng::Rng) -> Vec<(u64, u64, f64)> {
    let n = 1 + r.below(8) as usize;
    (0..n)
        .map(|_| (r.below(64), r.below(64), r.range_f64(0.0, 1.0)))
        .collect()
}

fn snapshot_of(tuples: &[(u64, u64, f64)]) -> KbSnapshot {
    KbSnapshot::from_records(tuples.iter().map(decode_record))
}

#[test]
fn merge_is_idempotent() {
    forall(11, 200, gen_records, |tuples| {
        let once = snapshot_of(tuples).encode();
        let doubled: Vec<_> = tuples.iter().chain(tuples.iter()).cloned().collect();
        let twice = snapshot_of(&doubled).encode();
        if once == twice {
            Ok(())
        } else {
            Err("merging a snapshot with itself changed it".into())
        }
    });
}

#[test]
fn merge_is_commutative() {
    forall(12, 200, gen_records, |tuples| {
        let forward = snapshot_of(tuples).encode();
        let reversed: Vec<_> = tuples.iter().rev().cloned().collect();
        let backward = snapshot_of(&reversed).encode();
        if forward == backward {
            Ok(())
        } else {
            Err("merge depends on record arrival order".into())
        }
    });
}

#[test]
fn merge_never_worsens_best_time() {
    forall(13, 200, gen_records, |tuples| {
        let snap = snapshot_of(tuples);
        for t in tuples {
            let rec = decode_record(t);
            let kept = snap
                .records()
                .find(|r| r.key == rec.key)
                .ok_or_else(|| format!("key {} vanished in merge", rec.key))?;
            if kept.profile.best_time > rec.profile.best_time {
                return Err(format!(
                    "kept {} but a {} record existed",
                    kept.profile.best_time, rec.profile.best_time
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn merge_through_stores_matches_snapshot_fold() {
    // The same fold through two actual store directories, in both orders,
    // lands on identical exported bytes (the bench-gate invariant).
    let dir_x = tmp("merge_x");
    let dir_y = tmp("merge_y");
    let a = snapshot_of(&[(0, 0, 0.5), (1, 1, 0.25), (3, 2, 0.125)]);
    let b = snapshot_of(&[(0, 0, 0.0625), (4, 3, 0.75), (3, 2, 0.125)]);
    let mut x = KbStore::open(&dir_x, "m-prop").unwrap();
    a.merge_into(&mut x);
    b.merge_into(&mut x);
    x.flush().unwrap();
    let mut y = KbStore::open(&dir_y, "m-prop").unwrap();
    b.merge_into(&mut y);
    a.merge_into(&mut y);
    y.flush().unwrap();
    assert_eq!(
        KbSnapshot::from_store(&x).encode(),
        KbSnapshot::from_store(&y).encode(),
        "store merge is order-dependent"
    );
    for d in [&dir_x, &dir_y] {
        let _ = std::fs::remove_dir_all(d);
    }
}
