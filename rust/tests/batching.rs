//! Propcheck harness for the serve batching/fusion layer (DESIGN.md
//! §2.10, ROADMAP item 5b): random mixes of compatible and incompatible
//! requests — saxpy at three sizes (sync-free, batchable) and the
//! global-sync nbody loop (solo-only), with randomly attached tiny
//! deadlines and huge priorities — are driven through `SessionPool::serve`
//! in batched and unbatched modes.
//!
//! Properties:
//!  * per-request results are bit-identical to solo unbatched runs
//!    (batching changes scheduling, never execution),
//!  * no cross-request aliasing: every stream index appears exactly once,
//!    batch provenance is consistent (members of a batch agree on its
//!    size; batch members are consecutive stream indices),
//!  * batch close honors SLO terms: a request whose deadline slack is
//!    below any fused estimate always drains solo (and is reported
//!    missed), a maximal-priority request shrinks its window to solo,
//!    and sync-bearing programs never ride in a batch.
//!
//! Failures replay deterministically: `forall` panics with the seed and
//! the shrunk counterexample, and re-running with that seed reproduces
//! the exact same case sequence (the simulator and the generator are both
//! seeded, and serve pools are rebuilt from constants per case).

use std::collections::BTreeMap;

use marrow::bench::workloads;
use marrow::kb::mk_profile;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::scheduler::SimEnv;
use marrow::session::serve::{ServeOpts, ServeReport, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};
use marrow::sim::machine::SimMachine;
use marrow::util::propcheck::forall;
use marrow::util::rng::Rng;

/// Far below any execution estimate: a request carrying this deadline has
/// zero batch slack and must always drain solo.
const TINY_DEADLINE: f64 = 1e-9;
/// Scales any batch window to effectively zero.
const HUGE_PRIORITY: u32 = 1_000_000_000;

/// Request kinds 0..=2 are sync-free saxpy sizes (batchable, kinds 1/2
/// seeded with opposite device leanings); kind 3 is the global-sync nbody
/// loop (solo-only).
fn comp(kind: u64) -> Computation {
    match kind {
        0 => Computation::from(workloads::saxpy(1 << 19)),
        1 => Computation::from(workloads::saxpy(1 << 20)),
        2 => Computation::from(workloads::saxpy(1 << 21)),
        _ => Computation::from(workloads::nbody(1 << 8, 2)),
    }
}

/// Decode one generated code: kind in the low bits, then an SLO flag
/// (none / tiny deadline / huge priority).
fn decode(code: u64) -> ServeRequest {
    let req = ServeRequest::from(comp(code % 4));
    match (code / 4) % 3 {
        1 => req.with_deadline(TINY_DEADLINE),
        2 => req.with_priority(HUGE_PRIORITY),
        _ => req,
    }
}

/// A random request mix: 1..=9 codes, each kind x flag.
fn gen_mix(r: &mut Rng) -> Vec<u64> {
    let len = 1 + r.below(9);
    (0..len).map(|_| r.below(12)).collect()
}

/// One single-session pool with a pre-seeded KB (no Algorithm 1 inside
/// the property, so cases are fast and estimates deterministic), zeroed
/// simulator noise, and a frozen balancer: given the same request
/// sequence, execution is bit-for-bit reproducible.
fn pool() -> SessionPool<SimEnv> {
    let pool = SessionPool::build(1, |i| {
        Session::sim(SimMachine::quiet(i7_hd7950(1), 7 + i as u64)).with_max_dev(10.0)
    });
    for (kind, cpu_share) in [(0, 0.5), (1, 0.9), (2, 0.1), (3, 0.5)] {
        let c = comp(kind);
        let (sct, w, _) = c.spec().unwrap();
        pool.shared_kb().write().unwrap().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            cpu_share,
            1e-3,
        ));
    }
    pool
}

fn run(requests: &[ServeRequest], batch_max: usize) -> ServeReport {
    pool()
        .serve(
            requests,
            &ServeOpts {
                batch_max,
                batch_window: 10.0,
                ..Default::default()
            },
        )
        .expect("serve")
}

/// Provenance sanity shared by both properties: indices complete and
/// unique, batch members agree on their batch's size, and every batch
/// covers consecutive stream indices (claims never skip or interleave).
fn check_provenance(report: &ServeReport, n: usize) -> Result<(), String> {
    if report.completed != n {
        return Err(format!("completed {} of {n}", report.completed));
    }
    let idx: Vec<usize> = report.traces.iter().map(|t| t.index).collect();
    if idx != (0..n).collect::<Vec<_>>() {
        return Err(format!("indices not exactly 0..{n}: {idx:?}"));
    }
    let mut by_batch: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for t in &report.traces {
        by_batch.entry(t.batch).or_default().push(t.index);
        if t.admit_wait > t.latency + 1e-9 {
            return Err(format!(
                "request {}: admit_wait {} exceeds latency {}",
                t.index, t.admit_wait, t.latency
            ));
        }
    }
    if by_batch.len() != report.batches {
        return Err(format!(
            "report.batches {} != distinct batch ids {}",
            report.batches,
            by_batch.len()
        ));
    }
    for (id, members) in &by_batch {
        for t in report.traces.iter().filter(|t| t.batch == *id) {
            if t.batch_size != members.len() {
                return Err(format!(
                    "batch {id}: member {} claims size {} but batch has {}",
                    t.index,
                    t.batch_size,
                    members.len()
                ));
            }
        }
        let lo = *members.iter().min().unwrap();
        let hi = *members.iter().max().unwrap();
        if hi - lo + 1 != members.len() {
            return Err(format!(
                "batch {id}: members {members:?} are not consecutive"
            ));
        }
    }
    Ok(())
}

#[test]
fn batched_results_are_bit_identical_to_solo_runs() {
    forall(41, 10, gen_mix, |codes| {
        let reqs: Vec<ServeRequest> = codes.iter().map(|&c| decode(c)).collect();
        let solo = run(&reqs, 1);
        let batched = run(&reqs, 4);
        check_provenance(&solo, reqs.len())?;
        check_provenance(&batched, reqs.len())?;
        if solo.traces.iter().any(|t| t.batch_size != 1) {
            return Err("unbatched run produced a multi-request batch".into());
        }
        for (s, b) in solo.traces.iter().zip(batched.traces.iter()) {
            if s.exec_total.to_bits() != b.exec_total.to_bits() {
                return Err(format!(
                    "request {}: batched exec {} != solo exec {} (bitwise)",
                    s.index, b.exec_total, s.exec_total
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn batch_close_honors_slo_terms_and_compatibility() {
    forall(43, 10, gen_mix, |codes| {
        let reqs: Vec<ServeRequest> = codes.iter().map(|&c| decode(c)).collect();
        let report = run(&reqs, 4);
        check_provenance(&report, reqs.len())?;
        for t in &report.traces {
            let code = codes[t.index];
            let (kind, flag) = (code % 4, (code / 4) % 3);
            if kind == 3 && t.batch_size != 1 {
                return Err(format!(
                    "request {}: sync-bearing program rode in a {}-batch",
                    t.index, t.batch_size
                ));
            }
            if flag == 1 {
                if t.batch_size != 1 {
                    return Err(format!(
                        "request {}: zero deadline slack but batch size {}",
                        t.index, t.batch_size
                    ));
                }
                if !t.deadline_missed {
                    return Err(format!(
                        "request {}: {TINY_DEADLINE}s deadline not reported missed",
                        t.index
                    ));
                }
            }
            if flag == 2 && t.batch_size != 1 {
                return Err(format!(
                    "request {}: maximal priority but batch size {}",
                    t.index, t.batch_size
                ));
            }
            if flag == 0 && t.deadline_missed {
                return Err(format!(
                    "request {}: deadline-free request reported missed",
                    t.index
                ));
            }
        }
        if report.p99_admit_wait < report.p50_admit_wait
            || report.p99_drain < report.p50_drain
        {
            return Err("latency-split percentiles out of order".into());
        }
        Ok(())
    });
}
