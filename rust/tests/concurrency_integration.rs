//! Integration tests for the concurrent execution core: the work-stealing
//! launcher's real overlap, the shareable `Session` facade, the serve
//! path's admission-cap scaling, and the balance monitor under interleaved
//! request streams.

use std::time::Duration;

use marrow::balance::{AdaptiveBinarySearch, Monitor};
use marrow::data::vector::ArgValue;
use marrow::decompose::{ExecSlot, Partition, PartitionPlan};
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::launcher::{launch, TaskOutput, TaskRunner};
use marrow::scheduler::queues::{Task, WorkQueues};
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, ConfigOrigin, Session};

/// Sleeps `0.N` ms per task unit; returns the task's unit range as output.
struct SleepPerUnit(u64);

impl TaskRunner for SleepPerUnit {
    fn run_task(&self, _slot: ExecSlot, task: &Task) -> marrow::Result<TaskOutput> {
        std::thread::sleep(Duration::from_millis(self.0 * task.partition.units));
        Ok(vec![ArgValue::F32(
            (task.partition.start_unit..task.partition.start_unit + task.partition.units)
                .map(|u| u as f32)
                .collect(),
        )]
        .into())
    }
}

/// Stalls only when *executed* on a CPU slot (stolen tasks run at the
/// thief's speed); returns the task's unit range.
struct CpuStall(u64);

impl TaskRunner for CpuStall {
    fn run_task(&self, slot: ExecSlot, task: &Task) -> marrow::Result<TaskOutput> {
        if slot.is_cpu() {
            std::thread::sleep(Duration::from_millis(self.0));
        }
        Ok(vec![ArgValue::F32(
            (task.partition.start_unit..task.partition.start_unit + task.partition.units)
                .map(|u| u as f32)
                .collect(),
        )]
        .into())
    }
}

fn hybrid_plan(slots: usize, units_per_slot: u64) -> PartitionPlan {
    PartitionPlan {
        partitions: (0..slots)
            .map(|i| Partition {
                slot: if i % 2 == 0 {
                    ExecSlot::CpuSub { idx: i as u32 }
                } else {
                    ExecSlot::GpuSlot {
                        gpu: 0,
                        slot: i as u32,
                    }
                },
                start_unit: i as u64 * units_per_slot,
                units: units_per_slot,
            })
            .collect(),
        quantum: 1,
        gpu_share: 0.5,
    }
}

/// Acceptance: with the stub runtime (no PJRT — tasks run fully parallel),
/// a hybrid drain's measured total is strictly less than the sum of the
/// per-slot times: the slots genuinely overlap instead of replaying
/// serially on one thread.
#[test]
fn hybrid_total_is_less_than_the_sum_of_slot_times() {
    let p = hybrid_plan(4, 4);
    let out = launch(WorkQueues::from_plan(&p), &SleepPerUnit(5)).unwrap();
    let slot_sum: f64 = out.clock.busy.iter().sum();
    assert_eq!(out.clock.busy.len(), 4);
    assert!(slot_sum >= 0.080, "4 x 20ms of work must be accounted for");
    assert!(
        out.clock.elapsed < slot_sum,
        "no overlap: total {} vs serial sum {}",
        out.clock.elapsed,
        slot_sum
    );
    // With 4 slots sleeping in parallel the margin is large; be strict
    // enough that a serial regression cannot slip through.
    assert!(
        out.clock.elapsed < 0.75 * slot_sum,
        "weak overlap: total {} vs serial sum {}",
        out.clock.elapsed,
        slot_sum
    );
}

/// Acceptance: two threads driving one shared `Session` both complete, and
/// the second request resolves as a KB hit produced by the first.
#[test]
fn shared_session_serves_two_threads_with_kb_reuse() {
    let comp = Computation::from(marrow::bench::workloads::saxpy(1 << 22));
    let session = Session::simulated(i7_hd7950(1), 21);
    let (tx, rx) = std::sync::mpsc::channel::<()>();

    std::thread::scope(|scope| {
        let s = &session;
        let c = &comp;
        let first = scope.spawn(move || {
            let out = s.run(c, &RequestArgs::default()).unwrap();
            tx.send(()).unwrap();
            out.origin
        });
        let second = scope.spawn(move || {
            // Wait for the first request to finish end-to-end, then issue
            // the second from this thread against the same facade.
            rx.recv().unwrap();
            let out = s.run(c, &RequestArgs::default()).unwrap();
            out.origin
        });
        assert_eq!(first.join().unwrap(), ConfigOrigin::Built);
        assert_eq!(second.join().unwrap(), ConfigOrigin::KbHit);
    });
    let st = session.stats();
    assert_eq!(st.runs, 2);
    assert_eq!(st.built, 1);
    assert_eq!(st.kb_hits, 1);
}

/// Acceptance: the serve path's requests/sec scales with the admission
/// cap — concurrency 4 is at least 2x concurrency 1. The pace floor stands
/// in for device occupancy (sleeps overlap across workers regardless of
/// host core count, so this holds on small CI machines too).
#[test]
fn serve_throughput_scales_with_concurrency() {
    let machine = i7_hd7950(1);
    let requests: Vec<ServeRequest> = (0..12)
        .map(|_| {
            ServeRequest::from(Computation::from(marrow::bench::workloads::saxpy(1 << 20)))
        })
        .collect();
    let pace = 0.010;
    let pool1 = SessionPool::build(1, |i| Session::simulated(machine.clone(), 31 + i as u64));
    let pool4 = SessionPool::build(4, |i| Session::simulated(machine.clone(), 131 + i as u64));
    // Warm the profile once, then share it with both pools, so the
    // comparison measures admission-cap scaling, not cold-start tuning.
    pool1
        .serve(&requests[..1], &ServeOpts { concurrency: 1, ..Default::default() })
        .unwrap();
    *pool4.shared_kb().write().unwrap() = pool1.shared_kb().read().unwrap().clone();
    let serial = pool1
        .serve(
            &requests,
            &ServeOpts {
                concurrency: 1,
                pace,
                ..Default::default()
            },
        )
        .unwrap();
    let parallel = pool4
        .serve(
            &requests,
            &ServeOpts {
                concurrency: 4,
                pace,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(serial.completed, 12);
    assert_eq!(parallel.completed, 12);
    assert!(
        parallel.requests_per_sec >= 2.0 * serial.requests_per_sec,
        "concurrency 4 gave {:.1} req/s vs {:.1} req/s at concurrency 1",
        parallel.requests_per_sec,
        serial.requests_per_sec
    );
}

/// Satellite: the balance monitor under concurrency. Two clients' slot-time
/// streams interleave into one shared monitor; a sustained CPU load spike
/// must take several consecutive unbalanced observations to trip the lbt
/// EWMA, trigger *exactly once*, and the adaptive binary search must settle
/// the CPU share strictly below the pre-spike split.
#[test]
fn interleaved_cpu_spike_triggers_lbt_once_and_lowers_share() {
    // Closed loop mirroring Session::run's balance block. Device rates:
    // cpu 1.0, gpu 1.0 pre-spike (optimum share 0.5); the spike halves the
    // CPU rate, moving the optimum to 1/3.
    let times = |share: f64, cpu_rate: f64| -> (f64, f64) {
        (share / cpu_rate, (1.0 - share) / 1.0)
    };
    let mut monitor = Monitor::new(0.8);
    let mut abs = AdaptiveBinarySearch::new(0.5);
    let mut share = 0.5;
    let mut triggers = 0u32;

    // Phase 1 — both interleaved clients observe balanced executions
    // (small per-client jitter keeps the streams distinct).
    for client in [0usize, 1, 0, 1, 0, 1, 0, 1] {
        let (ct, gt) = times(share, 1.0);
        let jitter = if client == 0 { 1.0 } else { 0.99 };
        let status = monitor.observe(&[ct * jitter, gt]);
        assert!(!status.unbalanced, "pre-spike stream must be balanced");
        assert!(!status.trigger);
        abs.track(share);
    }

    // Phase 2 — CPU load spike: the interleaved streams turn unbalanced.
    let mut first_trigger_at = None;
    for (i, client) in (0..20).map(|i| (i, i % 2)) {
        let (ct, gt) = times(share, 0.5);
        let jitter = if client == 0 { 1.0 } else { 1.01 };
        let status = monitor.observe(&[ct * jitter, gt]);
        if status.trigger {
            triggers += 1;
            first_trigger_at.get_or_insert(i + 1);
            share = abs.propose(ct, gt);
            monitor.reset_lbt();
        }
    }
    // The EWMA needs 3-4 consecutive unbalanced runs before the first
    // trigger (no single-observation overreaction)...
    let at = first_trigger_at.expect("spike must trigger the balancer");
    assert!((3..=4).contains(&at), "triggered after {at} observations");
    // ...the proposed share lands in the balanced region around the new
    // optimum, so the spike triggers exactly once...
    assert_eq!(triggers, 1, "lbt must trigger exactly once, share {share}");
    // ...and the search moved work off the loaded CPUs.
    assert!(share < 0.5, "share must drop below the pre-spike split");
    let (ct, gt) = times(share, 0.5);
    let dev = ct.min(gt) / ct.max(gt);
    assert!(dev >= 0.8, "post-rebalance split must be balanced: dev {dev}");

    // Phase 3 — the rebalanced interleaved streams stay quiet.
    for _ in 0..20 {
        let (ct, gt) = times(share, 0.5);
        let status = monitor.observe(&[ct, gt]);
        assert!(!status.trigger, "balanced post-spike stream re-triggered");
    }
}

/// The work-stealing launcher keeps a hybrid run correct when one slot
/// stalls: stolen tasks still merge in unit order.
#[test]
fn stalled_slot_work_is_stolen_and_merged_in_order() {
    // Slot 0 (cpu) carries 8 chunked tasks but stalls 10ms per task; slot 1
    // (gpu) finishes instantly and steals from slot 0's back end.
    let p = PartitionPlan {
        partitions: vec![
            Partition {
                slot: ExecSlot::CpuSub { idx: 0 },
                start_unit: 0,
                units: 64,
            },
            Partition {
                slot: ExecSlot::GpuSlot { gpu: 0, slot: 0 },
                start_unit: 64,
                units: 8,
            },
        ],
        quantum: 1,
        gpu_share: 0.1,
    };
    let queues = WorkQueues::from_plan_chunked(&p, 8);
    let out = launch(queues, &CpuStall(10)).unwrap();
    assert!(out.stolen > 0, "gpu slot must steal from the stalled cpu");
    // Concatenating seq-sorted partials reconstructs the domain exactly.
    let merged: Vec<f32> = out
        .partials
        .iter()
        .flat_map(|(_, o, _)| o[0].as_f32().unwrap().to_vec())
        .collect();
    let want: Vec<f32> = (0..72).map(|u| u as f32).collect();
    assert_eq!(merged, want);
}
