//! Backend-parity suite for the native compiled CPU backend (DESIGN.md
//! §2.11): every ported kernel family is compared against the
//! single-thread-scalar reference engine across the binding shapes the
//! runtime actually produces — chunked partitioned vectors (saxpy,
//! filters, FFT), loop-carried pipeline intermediates (the unfused
//! filter ablation), COPY-replicated vectors under a global-sync loop
//! (n-body), and both drain modes.
//!
//! Why the comparisons are *bitwise*: the native kernels vectorize only
//! across elements the source kernels treat independently (saxpy
//! elements, filter pixels, voxels, n-body `i` rows, whole FFT
//! transforms), and every lane variant runs the identical per-element
//! f32 operation sequence — the n-body `j` accumulation walks ascending
//! in all variants. No reassociation happens anywhere, so lanes=8,
//! lanes=4 and the scalar reference must agree bit for bit, and partial
//! outputs merge in unit order regardless of partitioning or stealing.
//! The only tolerance in this file is the FFT *roundtrip vs. input*
//! check, where f32 twiddle/butterfly roundoff is inherent (the
//! scalar-vs-vector comparison of the same FFT stays bitwise).

use std::sync::Arc;

use marrow::bench::workloads;
use marrow::data::image::{bodies, image, randn_vec, volume};
use marrow::data::vector::VectorArg;
use marrow::platform::device::host_cpu;
use marrow::runtime::exec::RequestArgs;
use marrow::runtime::native::{builtin_manifest, NativeArg, NativeEngine};
use marrow::scheduler::real::RealScheduler;
use marrow::scheduler::DrainMode;
use marrow::session::{Computation, ConfigOverride, Session};

type NativeSession = Session<RealScheduler<'static>>;

fn vector_session() -> NativeSession {
    Session::native(host_cpu()).expect("native session")
}

fn scalar_session() -> NativeSession {
    Session::native_with_engine(host_cpu(), Arc::new(NativeEngine::scalar_reference()))
        .expect("scalar-reference native session")
}

/// Run under a pinned config and pull every output out as f32 planes.
fn outputs_f32(
    s: &NativeSession,
    comp: &Computation,
    args: &RequestArgs,
    ovr: ConfigOverride,
) -> Vec<Vec<f32>> {
    let out = s.run_with(comp, args, ovr).expect("run_with");
    assert!(!out.outputs.is_empty(), "native backend returned no buffers");
    out.outputs
        .iter()
        .map(|o| o.as_f32().expect("f32 output").to_vec())
        .collect()
}

/// Bitwise comparison of two output sets, reporting the first diverging
/// element (f32 bits, so -0.0 vs 0.0 and NaN payloads count as drift).
fn assert_bitwise(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: output arity differs");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{what}: output {i} length differs");
        if let Some(j) = x
            .iter()
            .zip(y.iter())
            .position(|(u, v)| u.to_bits() != v.to_bits())
        {
            panic!(
                "{what}: output {i} diverges at elem {j}: {} vs {}",
                x[j], y[j]
            );
        }
    }
}

/// Shared filter request: one partitioned image plus the fused kernel's
/// scalar layout [seed, row_off placeholder, thresh] — identical cursor
/// order for the unfused 3-stage pipeline (gaussian consumes seed +
/// row_off, solarize consumes thresh).
fn filter_args(h: usize, w: usize) -> RequestArgs {
    RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", image(3, h, w), w as u64)],
        scalars: vec![12_345.0, 0.0, 96.0],
    }
}

#[test]
fn saxpy_parity_is_bitwise_across_lane_widths() {
    let n = 1usize << 18; // multiple of every saxpy chunk (4096 quantum)
    let comp = Computation::from(workloads::saxpy(n as u64));
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", randn_vec(1, n), 1),
            VectorArg::partitioned_f32("y", randn_vec(2, n), 1),
        ],
        scalars: vec![2.5],
    };
    let reference = outputs_f32(&scalar_session(), &comp, &args, ConfigOverride::new());
    assert_eq!(reference[0].len(), n);
    let v = vector_session();
    // wgs 256 -> lanes 8, wgs 64 -> lanes 4: distinct monomorphizations,
    // same per-element `a*x+y`, so both must match the scalar reference.
    for wgs in [256u32, 64] {
        let laned = outputs_f32(&v, &comp, &args, ConfigOverride::new().wgs(wgs));
        assert_bitwise(&laned, &reference, &format!("saxpy wgs={wgs}"));
    }
    // Spot-check against the definition itself, not just self-consistency.
    let (x, y) = (randn_vec(1, n), randn_vec(2, n));
    for i in [0usize, 4095, 4096, n - 1] {
        assert_eq!(reference[0][i].to_bits(), (2.5f32 * x[i] + y[i]).to_bits());
    }
}

#[test]
fn fused_filter_parity_holds_under_both_drain_modes() {
    let (h, w) = (512usize, 512usize);
    let comp = Computation::from(workloads::filter_pipeline(h as u64, w as u64, true));
    let args = filter_args(h, w);
    let mut per_mode = Vec::new();
    for mode in [DrainMode::Barrier, DrainMode::Dataflow] {
        let s = scalar_session();
        s.set_drain_mode(mode);
        let reference = outputs_f32(&s, &comp, &args, ConfigOverride::new());
        let v = vector_session();
        v.set_drain_mode(mode);
        let laned = outputs_f32(&v, &comp, &args, ConfigOverride::new());
        assert_bitwise(&laned, &reference, &format!("filter_pipeline {mode:?}"));
        per_mode.push(reference);
    }
    // The drain mode reorders task execution, never results: gauss_px
    // seeds noise from global pixel coordinates (row_off is the absolute
    // unit offset), so chunk decomposition cannot change the image.
    assert_bitwise(&per_mode[0], &per_mode[1], "filter_pipeline barrier vs dataflow");
}

#[test]
fn unfused_pipeline_carried_stages_match_fused_kernel() {
    let (h, w) = (512usize, 512usize);
    let args = filter_args(h, w);
    let unfused = Computation::from(workloads::filter_pipeline(h as u64, w as u64, false));
    let fused = Computation::from(workloads::filter_pipeline(h as u64, w as u64, true));
    // The 3-stage pipeline binds each stage's VecIn to the carried
    // producer output (Bind::Carried) — the loop-carried binding shape.
    let reference = outputs_f32(&scalar_session(), &unfused, &args, ConfigOverride::new());
    let laned = outputs_f32(&vector_session(), &unfused, &args, ConfigOverride::new());
    assert_bitwise(&laned, &reference, "unfused filter pipeline");
    // Fusion is exact: mirror(solarize(gauss(px))) per pixel, with the
    // same hash and clamp sequence — so the fused kernel must reproduce
    // the staged pipeline bit for bit on either engine.
    let fused_out = outputs_f32(&vector_session(), &fused, &args, ConfigOverride::new());
    assert_bitwise(&fused_out, &reference, "fused vs unfused filter");
}

#[test]
fn fft_roundtrip_parity_is_bitwise_and_accuracy_bounded() {
    let comp = Computation::from(workloads::fft(1)); // 1 MiB -> 256 transforms
    let n = 256 * 512usize;
    let re = randn_vec(5, n);
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("re", re.clone(), 512),
            VectorArg::partitioned_f32("im", randn_vec(6, n), 512),
        ],
        scalars: vec![],
    };
    // The FFT body is lane-independent (parallel axis = whole transforms,
    // the butterfly ladder is sequential), so parity is exact.
    let reference = outputs_f32(&scalar_session(), &comp, &args, ConfigOverride::new());
    let laned = outputs_f32(&vector_session(), &comp, &args, ConfigOverride::new());
    assert_bitwise(&laned, &reference, "fft_roundtrip");
    assert_eq!(reference.len(), 2, "fft emits re and im planes");
    // Roundtrip accuracy vs the *input* needs a tolerance: forward +
    // inverse is 18 butterfly rungs of f32 twiddle roundoff. For 512
    // points the error is ~eps*log2(n) relative to the signal scale
    // (~1e-6); 1e-4 of max|x| leaves margin while still catching any
    // indexing or normalization bug (those produce O(1) errors).
    let scale = re.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let worst = reference[0]
        .iter()
        .zip(&re)
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(
        worst <= 1e-4 * scale,
        "fft roundtrip drifted {worst} (scale {scale})"
    );
}

#[test]
fn nbody_global_sync_loop_parity_and_copy_residency_reuse() {
    let n = 2048usize;
    let comp = Computation::from(workloads::nbody(n as u64, 3));
    let args = RequestArgs {
        vectors: vec![VectorArg::copied_f32("pos", bodies(9, n))],
        scalars: vec![0.0], // Offset placeholder; the runtime substitutes
    };
    // Each lane keeps its own accumulator and walks j ascending, exactly
    // like the scalar loop — so even the O(n^2) sums are bit-identical.
    let reference = outputs_f32(&scalar_session(), &comp, &args, ConfigOverride::new());
    let v = vector_session();
    let laned = outputs_f32(&v, &comp, &args, ConfigOverride::new());
    assert_bitwise(&laned, &reference, "nbody_accel");
    assert_eq!(reference[0].len(), n * 3, "one xyz acceleration per body");
    // The COPY-replicated body set is keyed {start_unit: 0, whole vector}
    // in the residency pool: after the first chunk stages it, every later
    // chunk and every loop iteration must hit instead of re-uploading.
    assert!(
        v.stats().uploads_avoided > 0,
        "COPY vector was re-staged across chunks/iterations"
    );
}

#[test]
fn segmentation_direct_engine_parity() {
    // The workloads::segmentation plane (256x256 voxels/unit) has no
    // native artifact, so this family is exercised at the engine seam:
    // same dispatch the ChunkRunner performs, minus the scheduler.
    let manifest = builtin_manifest();
    let info = &manifest.family("segmentation").unwrap()[0]; // d8_h32_w32
    let vol = volume(4, 32, 32, 8);
    let thresholds = [96.0f32, 160.0];
    let args = [NativeArg::F32(&vol), NativeArg::F32(&thresholds)];
    let scalar = NativeEngine::scalar_reference()
        .run_chunk(info, 256, info.chunk_units, &args)
        .expect("scalar segmentation");
    let laned = NativeEngine::new()
        .run_chunk(info, 256, info.chunk_units, &args)
        .expect("laned segmentation");
    assert_bitwise(&laned, &scalar, "segmentation");
    // And against the classifier definition: every voxel lands exactly on
    // one of the three class levels, matching a direct evaluation.
    assert_eq!(scalar[0].len(), vol.len());
    for (o, v) in scalar[0].iter().zip(&vol) {
        let want: f32 = if *v < 96.0 {
            0.0
        } else if *v > 160.0 {
            255.0
        } else {
            128.0
        };
        assert_eq!(o.to_bits(), want.to_bits());
    }
}
