//! Irregular-workload propcheck suite (ROADMAP item 4, DESIGN.md §2.13):
//! random row-length distributions × steal-slack settings × both drain
//! modes drain the sparse/traversal kernels through the native CPU
//! scheduler, asserting
//!
//!  * native laned outputs are bit-identical to the single-thread-scalar
//!    reference — lanes only tile independent rows/nodes, every row keeps
//!    its own scalar inner loop, and chunk decomposition or stealing can
//!    never change what a row computes;
//!  * the drain mode reorders task execution, never results;
//!  * work stealing actually fires under row-length skew (accumulated
//!    across the random cases — skew is the *point* of this tier);
//!  * the KB's per-class cost models estimate within their own recorded
//!    dispersion envelope: for every observed run, the class estimate is
//!    within `sqrt(count) * dispersion * mean` per element of the
//!    observation (an identity of the population variance, so a violation
//!    means the model's accounting is wrong, not that the data is noisy).
//!
//! Failures shrink to a minimal counterexample and print a
//! `propcheck::replay(seed, case, ..)` line; the replay-pinning test
//! keeps the generator stream stable so that line reproduces the case.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use marrow::bench::workloads;
use marrow::data::irregular::{bfs_inputs, mandelbrot_plane, spmv_inputs};
use marrow::data::vector::VectorArg;
use marrow::data::workload::WorkloadClass;
use marrow::platform::device::{host_cpu, i7_hd7950};
use marrow::runtime::exec::RequestArgs;
use marrow::runtime::native::NativeEngine;
use marrow::scheduler::real::RealScheduler;
use marrow::scheduler::DrainMode;
use marrow::session::{Computation, ConfigOverride, ExecProfile, Session};
use marrow::util::propcheck;
use marrow::util::rng::Rng;

const SEED: u64 = 0xC0DE;
const CASES: usize = 5;

type NativeSession = Session<RealScheduler<'static>>;

/// One random case: (data-seed selector, row-count selector, steal-slack
/// selector, drain-mode selector). Raw u64s so the tuple Shrink applies;
/// the prop maps them into their domains.
type Case = (u64, u64, u64, u64);

fn gen(rng: &mut Rng) -> Case {
    (rng.below(4), rng.below(2), rng.below(3), rng.below(2))
}

/// Steals observed across every case of the forall — row-length skew makes
/// stealing *likely* per case, certain in aggregate (asserted after the
/// forall, on multi-core hosts only).
static STEALS: AtomicU64 = AtomicU64::new(0);

fn session(scalar: bool, tps: u32, mode: DrainMode) -> NativeSession {
    let s = if scalar {
        Session::native_with_engine(host_cpu(), Arc::new(NativeEngine::scalar_reference()))
    } else {
        Session::native(host_cpu())
    }
    .expect("native session");
    // The unified knob surface (DESIGN.md §2.13): one profile, one apply.
    s.apply_exec(&ExecProfile::new().tasks_per_slot(tps).drain_mode(mode));
    s
}

fn outputs_f32(
    s: &NativeSession,
    comp: &Computation,
    args: &RequestArgs,
) -> Result<Vec<Vec<f32>>, String> {
    let out = s
        .run_with(comp, args, ConfigOverride::new())
        .map_err(|e| format!("run failed: {e}"))?;
    Ok(out
        .outputs
        .iter()
        .map(|o| o.as_f32().expect("f32 output").to_vec())
        .collect())
}

fn first_bit_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> Option<(usize, usize)> {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.len() != y.len() {
            return Some((i, usize::MAX));
        }
        if let Some(j) = x
            .iter()
            .zip(y.iter())
            .position(|(u, v)| u.to_bits() != v.to_bits())
        {
            return Some((i, j));
        }
    }
    (a.len() != b.len()).then_some((a.len().min(b.len()), usize::MAX))
}

fn spmv_args(seed: u64, rows: usize) -> RequestArgs {
    let (cols, vals, x) = spmv_inputs(seed, rows, 16, 4096);
    RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("cols", cols, 16),
            VectorArg::partitioned_f32("vals", vals, 16),
            VectorArg::copied_f32("x", x),
        ],
        scalars: vec![],
    }
}

fn bfs_args(seed: u64, nodes: usize) -> RequestArgs {
    let (adj, frontier) = bfs_inputs(seed, nodes, 8, 4096);
    RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("adj", adj, 8),
            VectorArg::copied_f32("frontier", frontier),
        ],
        scalars: vec![],
    }
}

fn prop(case: &Case) -> Result<(), String> {
    let &(seed_sel, rows_sel, tps_sel, drain_sel) = case;
    let seed = 0xA5 + seed_sel; // picks the row-length distribution
    let rows = 256 * (1 + rows_sel as usize % 2); // 256 | 512 (chunk multiple)
    let tps = (2 + tps_sel % 3) as u32; // 2..=4 — always steal slack
    let mode = if drain_sel % 2 == 0 {
        DrainMode::Dataflow
    } else {
        DrainMode::Barrier
    };
    let ctx = format!("(seed={seed} rows={rows} tps={tps} {mode:?})");

    for (what, comp, args) in [
        (
            "spmv_csr",
            Computation::from(workloads::spmv(rows as u64)),
            spmv_args(seed, rows),
        ),
        (
            "bfs_frontier",
            Computation::from(workloads::bfs(rows as u64)),
            bfs_args(seed ^ 0x55, rows),
        ),
    ] {
        let reference = outputs_f32(&session(true, tps, mode), &comp, &args)?;
        let v = session(false, tps, mode);
        let laned = outputs_f32(&v, &comp, &args)?;
        if let Some((i, j)) = first_bit_diff(&laned, &reference) {
            return Err(format!(
                "{what} laned output diverges from scalar at output {i} \
                 elem {j} {ctx}"
            ));
        }
        // A second identical request runs over warm residency: a steal of
        // a task whose inputs sit on the victim slot forfeits them and is
        // counted. Accumulated across cases, not asserted per case.
        let again = outputs_f32(&v, &comp, &args)?;
        if first_bit_diff(&again, &reference).is_some() {
            return Err(format!("{what} second drain changed results {ctx}"));
        }
        STEALS.fetch_add(v.stats().steal_migrations, Ordering::Relaxed);
    }
    Ok(())
}

#[test]
fn irregular_native_parity_is_bitwise_under_random_skew() {
    propcheck::forall(SEED, CASES, gen, prop);
    if host_cpu().cpu.total_cores() > 1 {
        assert!(
            STEALS.load(Ordering::Relaxed) > 0,
            "row-length skew across {CASES} random cases (two drains each) \
             never triggered a steal migration — the irregular tier is not \
             exercising the work-stealing path"
        );
    }
}

#[test]
fn mandelbrot_native_parity_is_bitwise() {
    // Divergent class, fixed shape (one built-in 4096-pixel chunk): the
    // escape loop's trip count varies per pixel, the arithmetic does not.
    let comp = Computation::from(workloads::mandelbrot(4096, 256));
    let (re, im) = mandelbrot_plane(4096);
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("c_re", re, 1),
            VectorArg::partitioned_f32("c_im", im, 1),
        ],
        scalars: vec![256.0],
    };
    let reference =
        outputs_f32(&session(true, 2, DrainMode::Dataflow), &comp, &args).unwrap();
    let laned =
        outputs_f32(&session(false, 2, DrainMode::Dataflow), &comp, &args).unwrap();
    assert_eq!(first_bit_diff(&laned, &reference), None);
    // Escape counts are genuinely divergent: both extremes occur.
    assert!(reference[0].iter().any(|&v| v <= 2.0));
    assert!(reference[0].iter().any(|&v| v >= 256.0));
}

/// KB per-class estimates stay inside their own dispersion envelope: for
/// every observed run of an irregular class, `|estimate(elems) - secs| <=
/// sqrt(count) * dispersion * mean_spe * elems` (+ rounding slack). This
/// is an identity of the population variance the model records, so it
/// holds for ANY run history — a failure means the accounting (mean,
/// sum_sq, count) drifted from the observations that produced it.
fn kb_prop(case: &(u64, u64, u64)) -> Result<(), String> {
    let &(seed_sel, size_sel, runs_sel) = case;
    let rows = 4096u64 << (size_sel % 3); // 4096 | 8192 | 16384
    let runs = 2 + runs_sel as usize % 3; // 2..=4
    let mk = |r: u64| match seed_sel % 3 {
        0 => (workloads::spmv(r), WorkloadClass::Sparse),
        1 => (workloads::bfs(r), WorkloadClass::Traversal),
        _ => (workloads::mandelbrot(r, 256), WorkloadClass::Divergent),
    };
    let (b, class) = mk(rows);
    let s = Session::simulated(i7_hd7950(1), 500 + seed_sel);
    let comp = Computation::from(b);
    let mut observed: Vec<(u64, f64)> = Vec::new();
    for _ in 0..runs {
        let out = s
            .run(&comp, &RequestArgs::default())
            .map_err(|e| format!("sim run failed: {e}"))?;
        observed.push((rows, out.exec.total));
    }
    // A second size widens the spe spread the model must still contain.
    let (b2, _) = mk(rows * 2);
    let comp2 = Computation::from(b2);
    let out = s
        .run(&comp2, &RequestArgs::default())
        .map_err(|e| format!("sim run failed: {e}"))?;
    observed.push((rows * 2, out.exec.total));

    let kb = s.kb();
    let model = kb
        .class_model(class)
        .ok_or_else(|| format!("{class:?}: no class model after {} runs", observed.len()))?;
    if model.count < observed.len() as u64 {
        return Err(format!(
            "{class:?}: model saw {} observations, expected >= {}",
            model.count,
            observed.len()
        ));
    }
    let mean_spe = model.mean().ok_or("model has a count but no mean")?;
    let envelope = (model.count as f64).sqrt() * model.dispersion() * mean_spe;
    for &(elems, secs) in &observed {
        let est = kb
            .class_estimate(class, elems)
            .ok_or("class_estimate is None despite observations")?;
        let bound = envelope * elems as f64 + 1e-9 * secs.abs().max(1.0);
        if (est - secs).abs() > bound {
            return Err(format!(
                "{class:?} estimate {est:.6e} for {elems} elems is outside \
                 the dispersion envelope of observation {secs:.6e} \
                 (bound {bound:.6e}, count {}, dispersion {:.4})",
                model.count,
                model.dispersion()
            ));
        }
    }
    Ok(())
}

#[test]
fn class_estimates_stay_within_dispersion_envelope() {
    propcheck::forall(SEED ^ 0xFF, 6, |rng| (rng.below(3), rng.below(3), rng.below(3)), kb_prop);
}

/// The deterministic replay hook the forall failure message points at:
/// pinning case 0 keeps the generator stream stable — if the generator
/// changes shape, this fails before a real failure's replay line lies.
#[test]
fn failing_seed_replay_is_deterministic() {
    assert_eq!(propcheck::replay(SEED, 0, gen, prop), Ok(()));
    let mut rng = Rng::new(SEED);
    let first = gen(&mut rng);
    let mut rng2 = Rng::new(SEED);
    assert_eq!(first, gen(&mut rng2), "generator must be seed-deterministic");
}
