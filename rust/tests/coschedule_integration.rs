//! Device-space co-scheduling integration (DESIGN.md §2.8): the serve
//! path's slot reservations and KB-cost admission, exercised end-to-end in
//! `SimEnv` — no GPU required, and (with quiet cost parameters) fully
//! deterministic, so results can be compared to the bit.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use marrow::bench::workloads;
use marrow::data::vector::ArgValue;
use marrow::decompose::{ExecSlot, Partition, PartitionPlan};
use marrow::error::Result;
use marrow::kb::mk_profile;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::launcher::TaskOutput;
use marrow::scheduler::{
    launch_with, LaunchOpts, SimEnv, SlotMask, SlotReservations, Task, TaskRunner, WorkQueues,
};
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};
use marrow::sim::machine::SimMachine;

/// A session over a noise-free simulated machine ([`SimMachine::quiet`]):
/// pricing is a pure function of (plan, cost, config), so repeated runs
/// agree to the bit.
fn quiet_session(seed: u64) -> Session<SimEnv> {
    Session::sim(SimMachine::quiet(i7_hd7950(1), seed))
}

/// The heterogeneous pair: one CPU-leaning and one GPU-leaning request
/// (same kernel, different sizes, so they occupy distinct KB entries),
/// with pre-seeded profiles pinning the tuned splits — admission sees a
/// warm KB and the test controls the leanings exactly.
fn leaning_pair() -> (Computation, Computation) {
    (
        Computation::from(workloads::saxpy(1 << 20)),
        Computation::from(workloads::saxpy(1 << 21)),
    )
}

fn seed_kb<E: marrow::scheduler::ExecEnv>(session: &Session<E>, comp: &Computation, share: f64) {
    let (sct, w, _) = comp.spec().unwrap();
    session.kb_mut().store(mk_profile(
        &sct.id(),
        w.clone(),
        FissionLevel::L2,
        vec![4],
        share,
        1e-3,
    ));
}

fn seeded_pool() -> (SessionPool<SimEnv>, Computation, Computation) {
    let pool = SessionPool::build(2, |i| quiet_session(100 + i as u64));
    let (cpu_comp, gpu_comp) = leaning_pair();
    seed_kb(&pool.sessions()[0], &cpu_comp, 0.9);
    seed_kb(&pool.sessions()[0], &gpu_comp, 0.1);
    (pool, cpu_comp, gpu_comp)
}

/// The acceptance-criteria test: two concurrent heterogeneous requests
/// finish with strictly lower combined makespan under co-scheduling than
/// under the PR 2 whole-pool serialized drain, and each co-scheduled
/// request's result is bit-identical to a solo run on the same subset.
#[test]
fn co_scheduling_beats_whole_pool_serialization_with_identical_results() {
    let (pool, cpu_comp, gpu_comp) = seeded_pool();
    let reqs = vec![
        ServeRequest::from(cpu_comp.clone()),
        ServeRequest::from(gpu_comp.clone()),
    ];
    let serial = pool
        .serve(
            &reqs,
            &ServeOpts {
                concurrency: 2,
                ..Default::default()
            },
        )
        .unwrap();
    let (pool, _, _) = seeded_pool();
    let co = pool
        .serve(
            &reqs,
            &ServeOpts {
                concurrency: 2,
                co_schedule: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(serial.completed, 2);
    assert_eq!(co.completed, 2);

    // The CPU-leaning request lands on the CPU device, the GPU-leaning one
    // on the GPU: disjoint subsets, so the requests genuinely co-execute.
    let masks: Vec<&SlotMask> = co.traces.iter().map(|t| t.mask.as_ref().unwrap()).collect();
    assert!(
        !masks[0].conflicts(masks[1]),
        "heterogeneous requests must land on disjoint subsets: {} vs {}",
        masks[0],
        masks[1]
    );

    // Strictly lower combined makespan than the serialized whole-pool
    // drain (which stacks every request on the virtual timeline).
    assert!(
        co.virtual_makespan < serial.virtual_makespan,
        "co-scheduled makespan {} must beat serialized {}",
        co.virtual_makespan,
        serial.virtual_makespan
    );

    // Per-request results bit-identical to solo runs: a fresh session with
    // the same profile and the same mask prices the same execution.
    for trace in &co.traces {
        let comp = if trace.index == 0 { &cpu_comp } else { &gpu_comp };
        let solo = quiet_session(999);
        seed_kb(&solo, comp, if trace.index == 0 { 0.9 } else { 0.1 });
        solo.set_slot_mask(trace.mask.clone());
        let out = solo.run(comp, &RequestArgs::default()).unwrap();
        assert_eq!(
            out.exec.total.to_bits(),
            trace.exec_total.to_bits(),
            "request {} on {} must price identically solo",
            trace.index,
            trace.mask.as_ref().unwrap()
        );
    }
}

/// Masked runs are quarantined from learning: a burst of subset-restricted
/// executions must neither refine the shared profile (their totals and
/// slot times describe the reservation, not the machine) nor trip the
/// balance machinery.
#[test]
fn masked_runs_do_not_feed_balancer_or_kb() {
    let machine = i7_hd7950(1);
    let comp = Computation::from(workloads::saxpy(1 << 20));
    let s = quiet_session(77);
    seed_kb(&s, &comp, 0.9);
    s.set_slot_mask(Some(SlotMask::cpu_only(&machine)));
    for _ in 0..6 {
        s.run(&comp, &RequestArgs::default()).unwrap();
    }
    s.set_slot_mask(None);
    let (sct, w, _) = comp.spec().unwrap();
    {
        let kb = s.kb();
        let p = kb.lookup(&sct.id(), w).unwrap();
        assert_eq!(p.config.cpu_share, 0.9, "masked runs must not refine");
        assert_eq!(p.best_time, 1e-3, "masked totals must not update best_time");
    }
    let stats = s.stats();
    assert_eq!(stats.runs, 6);
    assert_eq!(stats.balance_ops, 0);
    assert_eq!(stats.unbalanced_runs, 0);
}

/// A request needing the whole pool while subsets are held must queue —
/// and complete once the subsets release — never deadlock.
#[test]
fn wide_request_queues_behind_subsets_without_deadlock() {
    let machine = i7_hd7950(1);
    let reg = Arc::new(SlotReservations::new());
    let cpu = reg.try_acquire(SlotMask::cpu_only(&machine), 1.0).unwrap();
    let gpu = reg.try_acquire(SlotMask::all_gpus(&machine), 1.0).unwrap();
    assert!(reg.try_acquire(SlotMask::full(&machine), 1.0).is_none());

    let reg2 = reg.clone();
    let m2 = machine.clone();
    let waiter = std::thread::spawn(move || {
        let _g = reg2.acquire(SlotMask::full(&m2), 1.0);
    });
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        !waiter.is_finished(),
        "full-pool request must queue while subsets are held"
    );
    drop(cpu);
    std::thread::sleep(Duration::from_millis(50));
    assert!(!waiter.is_finished(), "one conflicting holder remains");
    drop(gpu);
    waiter.join().expect("queued request must complete, not deadlock");
    assert_eq!(reg.active_len(), 0);
}

/// A reservation guard releases on unwind: a panicking request can never
/// leak its slots.
#[test]
fn reservation_releases_on_request_panic() {
    let machine = i7_hd7950(1);
    let reg = Arc::new(SlotReservations::new());
    let reg2 = reg.clone();
    let m2 = machine.clone();
    let joined = std::thread::spawn(move || {
        let _g = reg2.acquire(SlotMask::full(&m2), 1.0);
        panic!("request died mid-flight");
    })
    .join();
    assert!(joined.is_err(), "the worker must have panicked");
    assert_eq!(reg.active_len(), 0, "unwind must release the reservation");
    assert!(reg.try_acquire(SlotMask::full(&machine), 1.0).is_some());
}

/// A failing request cancels the stream (serve returns the error) and the
/// pool — sessions and masks — stays usable for the next serve call.
#[test]
fn failing_request_cancels_stream_and_frees_the_pool() {
    use marrow::sct::{KernelSpec, ParamSpec, Sct};
    let (pool, cpu_comp, _) = seeded_pool();
    // No workload/units attached: Session::run rejects it.
    let bad = Computation::from_sct(Sct::kernel(KernelSpec::new(
        "orphan",
        vec![ParamSpec::VecIn],
        1,
    )));
    let reqs = vec![ServeRequest::from(bad), ServeRequest::from(cpu_comp.clone())];
    let err = pool
        .serve(
            &reqs,
            &ServeOpts {
                concurrency: 2,
                co_schedule: true,
                ..Default::default()
            },
        )
        .unwrap_err();
    assert!(!format!("{err}").is_empty());
    // The pool serves fine afterwards — no leaked mask, no poisoned state.
    let ok = pool
        .serve(
            &[ServeRequest::from(cpu_comp)],
            &ServeOpts {
                concurrency: 2,
                co_schedule: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(ok.completed, 1);
}

/// Two concurrent cold requests (different SCT dimensionalities, so both
/// must build) keep the shared `Arc<RwLock<KnowledgeBase>>` consistent:
/// one profile per (SCT, workload), both retrievable.
#[test]
fn concurrent_cold_requests_keep_shared_kb_consistent() {
    let pool = SessionPool::build(2, |i| quiet_session(40 + i as u64));
    let a = Computation::from(workloads::saxpy(1 << 18));
    let b = Computation::from(workloads::filter_pipeline(256, 256, true));
    let reqs: Vec<ServeRequest> = (0..8)
        .map(|i| {
            ServeRequest::from(if i % 2 == 0 { a.clone() } else { b.clone() })
        })
        .collect();
    let report = pool
        .serve(
            &reqs,
            &ServeOpts {
                concurrency: 2,
                co_schedule: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(report.completed, 8);
    let kb = pool.shared_kb();
    let kb = kb.read().unwrap();
    assert_eq!(kb.len(), 2, "exactly one profile per (SCT, workload)");
    for comp in [&a, &b] {
        let (sct, w, _) = comp.spec().unwrap();
        assert!(kb.lookup(&sct.id(), w).is_some());
    }
    assert!(report.stats.built >= 2, "both cold pairs must have built");
}

/// Launcher-level boundary: a masked drain completes every task without a
/// single execution landing on an excluded slot — stealing cannot cross a
/// reservation.
#[test]
fn masked_drain_never_executes_outside_the_reservation() {
    struct SlotRecorder(Mutex<Vec<ExecSlot>>);
    impl TaskRunner for SlotRecorder {
        fn run_task(&self, slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
            self.0.lock().unwrap().push(slot);
            Ok(vec![ArgValue::F32(vec![task.partition.start_unit as f32])].into())
        }
    }
    let plan = PartitionPlan {
        partitions: vec![
            Partition {
                slot: ExecSlot::GpuSlot { gpu: 0, slot: 0 },
                start_unit: 0,
                units: 64,
            },
            Partition {
                slot: ExecSlot::CpuSub { idx: 0 },
                start_unit: 64,
                units: 64,
            },
        ],
        quantum: 1,
        gpu_share: 0.5,
    };
    let queues = WorkQueues::from_plan_chunked(&plan, 4);
    let n_tasks = queues.n_tasks();
    let recorder = SlotRecorder(Mutex::new(Vec::new()));
    let out = launch_with(
        queues,
        &recorder,
        LaunchOpts {
            policy: None,
            mask: Some(SlotMask {
                cpu: true,
                gpus: vec![false],
            }),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(out.partials.len(), n_tasks, "every task must still run");
    let slots = recorder.0.into_inner().unwrap();
    assert!(
        slots.iter().all(|s| s.is_cpu()),
        "no execution may land outside the reservation: {slots:?}"
    );
}
