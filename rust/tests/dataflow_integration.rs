//! Dataflow-drain integration tests (DESIGN.md §2.7), all runnable in the
//! stub build:
//!
//!  * barrier and dataflow drains produce *bit-identical* outputs on
//!    pipeline and (early-stopping, host-updated) loop workloads — the
//!    drains run the same per-chunk math through the chunked queues and
//!    the task graph respectively;
//!  * the simulated backend prices the dataflow drain strictly below the
//!    barrier drain (makespan and mean slot idle) on multi-stage work —
//!    the PR's acceptance criterion, also reported by BENCH_pr4.json;
//!  * graph steals are priced against resident bytes including downstream
//!    consumers, and the session / serve layers expose the drain-mode knob
//!    and the idle accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use marrow::bench::workloads;
use marrow::data::vector::ArgValue;
use marrow::decompose::graph::{build_graph, flatten_stages, TaskNode};
use marrow::decompose::{decompose, DecomposeConfig, ExecSlot, Partition, PartitionPlan};
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::runtime::residency::ResidencyView;
use marrow::scheduler::launcher::TaskOutput;
use marrow::scheduler::{
    launch, launch_graph, DrainMode, ExecEnv, GraphRunner, LaunchOpts, SimEnv, StealPolicy,
    SyncOutcome, SyncVerdict, Task, TaskRunner, WorkQueues,
};
use marrow::sct::{KernelSpec, ParamSpec, Sct};
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, ExecProfile, Session};
use marrow::sim::machine::SimMachine;
use marrow::tuner::profile::FrameworkConfig;
use marrow::Result;

const TASKS_PER_SLOT: u32 = 3;

fn kernel(name: &str) -> Sct {
    Sct::kernel(KernelSpec::new(name, vec![ParamSpec::VecIn], 1))
}

fn pipeline_sct(n: usize) -> Sct {
    Sct::pipeline((0..n).map(|i| kernel(&format!("k{i}"))).collect())
}

fn plan_for(sct: &Sct, total: u64) -> PartitionPlan {
    decompose(
        sct,
        total,
        &DecomposeConfig {
            cpu_subdevices: 2,
            gpu_overlap: vec![2],
            gpu_weights: vec![1.0],
            cpu_share: 0.4,
            wgs: 1,
            chunk_quantum: 4,
        },
    )
    .unwrap()
}

/// The synthetic per-element "kernel" both drains run: rounding-order
/// sensitive enough that any reordering of the per-chunk math would show
/// up in the bit comparison.
fn seed(u: u64) -> f32 {
    u as f32 * 0.37 + 0.11
}

fn apply(stage: u32, x: f32) -> f32 {
    x * 1.7 + (stage as f32 + 1.0) * 0.25
}

/// Barrier side of the pipeline parity test: one task runs every stage
/// chained over its chunk — exactly the pre-dataflow executor's shape.
struct BarrierPipeline {
    n_stages: u32,
}

impl TaskRunner for BarrierPipeline {
    fn run_task(&self, _slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
        let p = &task.partition;
        let mut vals: Vec<f32> = (p.start_unit..p.start_unit + p.units).map(seed).collect();
        for s in 0..self.n_stages {
            for v in vals.iter_mut() {
                *v = apply(s, *v);
            }
        }
        Ok(vec![ArgValue::F32(vals)].into())
    }
}

/// Dataflow side: one node per (stage × chunk), stage input carried from
/// the producer chunk.
struct DataflowPipeline;

impl GraphRunner for DataflowPipeline {
    fn run_node(
        &self,
        _slot: ExecSlot,
        node: &TaskNode,
        carried: Option<&[ArgValue]>,
    ) -> Result<TaskOutput> {
        let p = &node.partition;
        let base: Vec<f32> = match carried {
            Some(c) => c[0].as_f32()?.to_vec(),
            None => (p.start_unit..p.start_unit + p.units).map(seed).collect(),
        };
        Ok(vec![ArgValue::F32(
            base.into_iter().map(|x| apply(node.stage, x)).collect(),
        )]
        .into())
    }

    fn run_sync(
        &self,
        _node: &TaskNode,
        _gathered: &[(usize, std::sync::Arc<Vec<ArgValue>>)],
        _is_sink: bool,
    ) -> Result<SyncOutcome> {
        Ok(SyncOutcome {
            verdict: SyncVerdict::Continue,
            outputs: None,
        })
    }
}

fn concat_f32(parts: Vec<Vec<ArgValue>>) -> Vec<u32> {
    let mut out = Vec::new();
    for p in parts {
        out.extend(p[0].as_f32().unwrap().iter().map(|x| x.to_bits()));
    }
    out
}

#[test]
fn pipeline_outputs_bit_identical_across_drain_modes() {
    let sct = pipeline_sct(3);
    let total = 257; // off-quantum tail exercises the residue chunk
    let plan = plan_for(&sct, total);

    let barrier = {
        let queues = WorkQueues::from_plan_chunked(&plan, TASKS_PER_SLOT);
        let out = launch(queues, &BarrierPipeline { n_stages: 3 }).unwrap();
        concat_f32(out.into_outputs())
    };

    let dataflow = {
        let stages = flatten_stages(&sct).unwrap();
        let graph = build_graph(&stages, &plan, TASKS_PER_SLOT).unwrap();
        let out = launch_graph(&graph, &DataflowPipeline, LaunchOpts::default()).unwrap();
        assert!(out.outputs.is_none());
        concat_f32(out.partials.into_iter().map(|(_, o)| o).collect())
    };

    assert_eq!(barrier.len(), total as usize);
    assert_eq!(barrier, dataflow, "drain modes must agree to the bit");
}

// ---------------------------------------------------------------------------
// Loop parity: host-updated state, early stoppage.
// ---------------------------------------------------------------------------

/// Shared host-update logic of both drains: fold the iteration's outputs
/// into the loop state (in unit order — rounding-order sensitive) and stop
/// after iteration 2 of 5.
fn loop_update(iter: u32, state: f32, outs: &[f32]) -> (f32, bool) {
    let mut s = state;
    for v in outs {
        s += v * 1e-3;
    }
    (s, iter < 2)
}

fn loop_body(state: f32, u: u64) -> f32 {
    seed(u) * 0.9 + state
}

struct BarrierLoopIter {
    state: f32,
}

impl TaskRunner for BarrierLoopIter {
    fn run_task(&self, _slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
        let p = &task.partition;
        let vals: Vec<f32> = (p.start_unit..p.start_unit + p.units)
            .map(|u| loop_body(self.state, u))
            .collect();
        Ok(vec![ArgValue::F32(vals)].into())
    }
}

struct DataflowLoop {
    state: Mutex<f32>,
}

impl GraphRunner for DataflowLoop {
    fn run_node(
        &self,
        _slot: ExecSlot,
        node: &TaskNode,
        _carried: Option<&[ArgValue]>,
    ) -> Result<TaskOutput> {
        let st = *self.state.lock().unwrap();
        let p = &node.partition;
        let vals: Vec<f32> = (p.start_unit..p.start_unit + p.units)
            .map(|u| loop_body(st, u))
            .collect();
        Ok(vec![ArgValue::F32(vals)].into())
    }

    fn run_sync(
        &self,
        node: &TaskNode,
        gathered: &[(usize, std::sync::Arc<Vec<ArgValue>>)],
        is_sink: bool,
    ) -> Result<SyncOutcome> {
        let iter = node.stage / 2; // stage pairs: [body, sync] per iteration
        let mut whole = Vec::new();
        for (_, o) in gathered {
            whole.extend_from_slice(o[0].as_f32()?);
        }
        let mut st = self.state.lock().unwrap();
        let (ns, go) = loop_update(iter, *st, &whole);
        *st = ns;
        let brk = !go;
        Ok(SyncOutcome {
            verdict: if brk {
                SyncVerdict::Break
            } else {
                SyncVerdict::Continue
            },
            outputs: if brk || is_sink {
                Some(vec![ArgValue::F32(whole)])
            } else {
                None
            },
        })
    }
}

#[test]
fn loop_outputs_bit_identical_across_drain_modes_with_early_stop() {
    let sct = Sct::for_loop(kernel("body"), 5, true);
    let total = 192u64;
    let plan = plan_for(&sct, total);

    // Barrier reference: iterate launch() with the state update between
    // iterations, stopping when the condition fails.
    let barrier = {
        let mut state = 0.0f32;
        let mut last = Vec::new();
        for iter in 0..5u32 {
            let queues = WorkQueues::from_plan_chunked(&plan, TASKS_PER_SLOT);
            let out = launch(queues, &BarrierLoopIter { state }).unwrap();
            let mut whole = Vec::new();
            for o in out.into_outputs() {
                whole.extend_from_slice(o[0].as_f32().unwrap());
            }
            let (ns, go) = loop_update(iter, state, &whole);
            state = ns;
            last = whole;
            if !go {
                break;
            }
        }
        last.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
    };

    let (dataflow, executed, n_nodes) = {
        let stages = flatten_stages(&sct).unwrap();
        let graph = build_graph(&stages, &plan, TASKS_PER_SLOT).unwrap();
        let runner = DataflowLoop {
            state: Mutex::new(0.0),
        };
        let out = launch_graph(&graph, &runner, LaunchOpts::default()).unwrap();
        let outs = out.outputs.expect("breaking loop sync must yield outputs");
        (
            outs[0]
                .as_f32()
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<u32>>(),
            out.executed,
            graph.n_nodes() as u64,
        )
    };

    assert_eq!(barrier, dataflow, "loop drains must agree to the bit");
    assert!(
        executed < n_nodes,
        "iterations past the stoppage condition must be cancelled \
         ({executed} of {n_nodes} ran)"
    );
}

// ---------------------------------------------------------------------------
// Steal pricing with downstream residency.
// ---------------------------------------------------------------------------

struct FixedResidency {
    bytes: u64,
    migrations: AtomicU64,
    skips: AtomicU64,
}

impl ResidencyView for FixedResidency {
    fn resident_range_bytes(&self, _slot: ExecSlot, _start: u64, _units: u64) -> u64 {
        self.bytes
    }

    fn note_migration(&self, _f: ExecSlot, _t: ExecSlot, _s: u64, _u: u64) -> u64 {
        self.migrations.fetch_add(1, Ordering::Relaxed);
        self.bytes
    }

    fn note_steal_skipped(&self) {
        self.skips.fetch_add(1, Ordering::Relaxed);
    }
}

/// Slow per-node runner so the light CPU slot goes idle while the GPU
/// queue still holds stealable graph nodes.
struct SlowPipeline;

impl GraphRunner for SlowPipeline {
    fn run_node(
        &self,
        _slot: ExecSlot,
        node: &TaskNode,
        _carried: Option<&[ArgValue]>,
    ) -> Result<TaskOutput> {
        std::thread::sleep(Duration::from_millis(2));
        Ok(vec![ArgValue::F32(vec![0.0; node.partition.units as usize])].into())
    }

    fn run_sync(
        &self,
        _node: &TaskNode,
        _gathered: &[(usize, std::sync::Arc<Vec<ArgValue>>)],
        _is_sink: bool,
    ) -> Result<SyncOutcome> {
        Ok(SyncOutcome {
            verdict: SyncVerdict::Continue,
            outputs: None,
        })
    }
}

fn lopsided_plan() -> PartitionPlan {
    PartitionPlan {
        partitions: vec![
            Partition {
                slot: ExecSlot::GpuSlot { gpu: 0, slot: 0 },
                start_unit: 0,
                units: 64,
            },
            Partition {
                slot: ExecSlot::CpuSub { idx: 0 },
                start_unit: 64,
                units: 4,
            },
        ],
        quantum: 1,
        gpu_share: 64.0 / 68.0,
    }
}

#[test]
fn graph_steals_skipped_when_resident_data_prices_them_out() {
    let sct = pipeline_sct(2);
    let plan = lopsided_plan();
    let stages = flatten_stages(&sct).unwrap();
    let graph = build_graph(&stages, &plan, 8).unwrap();
    let residency = FixedResidency {
        bytes: 1 << 30,
        migrations: AtomicU64::new(0),
        skips: AtomicU64::new(0),
    };
    let out = launch_graph(
        &graph,
        &SlowPipeline,
        LaunchOpts {
            policy: Some(StealPolicy {
                residency: &residency,
                secs_per_byte: 1.0,
                default_task_secs: 1e-6,
            }),
            mask: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(out.stolen, 0, "no node may migrate away from its data");
    assert!(out.steals_skipped > 0, "rejections must be counted");
    assert_eq!(residency.migrations.load(Ordering::Relaxed), 0);
    assert_eq!(
        residency.skips.load(Ordering::Relaxed),
        out.steals_skipped,
        "every rejection is booked against the residency oracle"
    );
    assert_eq!(out.executed as usize, graph.n_nodes());
}

#[test]
fn graph_steals_admitted_and_booked_when_migration_is_free() {
    let sct = pipeline_sct(2);
    let plan = lopsided_plan();
    let stages = flatten_stages(&sct).unwrap();
    let graph = build_graph(&stages, &plan, 8).unwrap();
    let residency = FixedResidency {
        bytes: 64,
        migrations: AtomicU64::new(0),
        skips: AtomicU64::new(0),
    };
    let out = launch_graph(
        &graph,
        &SlowPipeline,
        LaunchOpts {
            policy: Some(StealPolicy {
                residency: &residency,
                secs_per_byte: 1e-12,
                default_task_secs: 0.05,
            }),
            mask: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(out.stolen > 0, "cheap migrations must be admitted");
    assert!(residency.migrations.load(Ordering::Relaxed) >= out.stolen);
    assert_eq!(out.executed as usize, graph.n_nodes());
}

// ---------------------------------------------------------------------------
// Simulated acceptance: dataflow strictly beats barrier on multi-stage work.
// ---------------------------------------------------------------------------

fn quiet_env(seed: u64) -> SimEnv {
    SimEnv::new(SimMachine::quiet(i7_hd7950(1), seed))
}

fn cfg() -> FrameworkConfig {
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: 0.25,
    }
}

/// A compute-bound pipeline stage: per-stage pricing is exactly linear in
/// flops, so barrier and dataflow busy clocks agree and the comparison
/// isolates the drain structure (stage-maxima sum + gates vs slot max).
fn flops_kernel(name: &str, flops: f64) -> Sct {
    let mut k = KernelSpec::new(name, vec![ParamSpec::VecIn], 1);
    k.flops_per_unit = flops;
    k.bytes_per_unit = 8.0;
    k.passes = 1.0;
    Sct::kernel(k)
}

#[test]
fn sim_dataflow_strictly_beats_barrier_on_pipeline_and_loop() {
    let pipeline = Sct::pipeline(vec![
        flops_kernel("fa", 5000.0),
        flops_kernel("fb", 3000.0),
        flops_kernel("fc", 4000.0),
    ]);
    let looped = Sct::for_loop(
        Sct::pipeline(vec![flops_kernel("la", 4000.0), flops_kernel("lb", 2500.0)]),
        5,
        true,
    );
    let cases: Vec<(&str, &Sct, u64)> = vec![
        ("pipeline", &pipeline, 1 << 16),
        ("loop", &looped, 1 << 14),
    ];
    for (name, sct, units) in cases {
        let mut df = quiet_env(7);
        let mut bar = quiet_env(7);
        bar.set_drain_mode(DrainMode::Barrier);
        let d = df
            .run_request(sct, &RequestArgs::default(), units, &cfg())
            .unwrap()
            .exec;
        let b = bar
            .run_request(sct, &RequestArgs::default(), units, &cfg())
            .unwrap()
            .exec;
        assert!(
            d.total < b.total,
            "{name}: dataflow makespan {} must beat barrier {}",
            d.total,
            b.total
        );
        assert!(
            d.mean_idle_frac() < b.mean_idle_frac(),
            "{name}: dataflow idle {} must beat barrier {}",
            d.mean_idle_frac(),
            b.mean_idle_frac()
        );
    }
    // The memory-bound staged filter pipeline: the makespan ordering is
    // structural (per-slot aggregate pricing never exceeds the per-stage
    // sum, and the barrier gate is strictly positive), so it must hold
    // here too.
    let filter = workloads::filter_pipeline(2048, 2048, false);
    let mut df = quiet_env(9);
    let mut bar = quiet_env(9);
    bar.set_drain_mode(DrainMode::Barrier);
    let d = df
        .run_request(&filter.sct, &RequestArgs::default(), filter.total_units, &cfg())
        .unwrap()
        .exec;
    let b = bar
        .run_request(&filter.sct, &RequestArgs::default(), filter.total_units, &cfg())
        .unwrap()
        .exec;
    assert!(
        d.total < b.total,
        "filter: dataflow makespan {} must beat barrier {}",
        d.total,
        b.total
    );
}

// ---------------------------------------------------------------------------
// Session / serve wiring.
// ---------------------------------------------------------------------------

#[test]
fn session_and_serve_expose_drain_mode_and_idle_accounting() {
    let comp = Computation::from(workloads::filter_pipeline(1024, 1024, false));
    let s = Session::simulated(i7_hd7950(1), 3).with_drain_mode(DrainMode::Barrier);
    let out = s.run(&comp, &RequestArgs::default()).unwrap();
    assert!(out.exec.mean_idle_frac() > 0.0, "barrier drains idle slots");
    let st = s.stats();
    assert!(st.idle_frac_sum > 0.0);
    assert!(st.mean_idle_pct() > 0.0);

    let pool = SessionPool::build(2, |i| Session::simulated(i7_hd7950(1), 60 + i as u64));
    let reqs: Vec<ServeRequest> = (0..4).map(|_| ServeRequest::from(comp.clone())).collect();
    let report = pool
        .serve(
            &reqs,
            &ServeOpts {
                concurrency: 2,
                exec: ExecProfile::new().drain_mode(DrainMode::Barrier),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(report.completed, 4);
    assert!(report.stats.idle_frac_sum > 0.0);
    assert!(report.summary().contains("slot idle"), "{}", report.summary());
}
