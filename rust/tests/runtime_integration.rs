//! Integration tests over the real PJRT runtime: AOT artifacts -> compile
//! -> chunked execution -> scheduler-level merging. These exercise the
//! cross-module composition the lib tests mock out.
//!
//! They require `make artifacts`; every test no-ops (with a note) when the
//! manifest is absent so `cargo test` stays green pre-build.

use std::path::PathBuf;
use std::sync::Arc;

use marrow::bench::workloads;
use marrow::data::image::{bodies, image, randn_vec, volume};
use marrow::data::vector::{ArgValue, VectorArg};
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::{ChunkRunner, RequestArgs};
use marrow::scheduler::real::RealScheduler;
use marrow::sct::Sct;
use marrow::tuner::profile::FrameworkConfig;

fn manifest() -> Option<Manifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping integration test");
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

fn cfg(share: f64) -> FrameworkConfig {
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: share,
    }
}

#[test]
fn saxpy_partition_chunks_match_host() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let runner = ChunkRunner::new(&client, &man);
    let n = 8192usize;
    let x = randn_vec(1, n);
    let y = randn_vec(2, n);
    let b = workloads::saxpy(n as u64);
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", x.clone(), 1),
            VectorArg::partitioned_f32("y", y.clone(), 1),
        ],
        scalars: vec![3.0],
    };
    let outs = runner.run_tree(&b.sct, &args, 0, n as u64).unwrap();
    let got = outs[0].as_f32().unwrap();
    for i in 0..n {
        assert!((got[i] - (3.0 * x[i] + y[i])).abs() < 1e-4, "elem {i}");
    }
    // 8192 = 2 x 4096-chunks.
    assert_eq!(runner.launch_count(), 2);
}

#[test]
fn super_chunk_selection_reduces_launches() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let n = 32768u64;
    let b = workloads::saxpy(n);
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", randn_vec(3, n as usize), 1),
            VectorArg::partitioned_f32("y", randn_vec(4, n as usize), 1),
        ],
        scalars: vec![1.0],
    };
    let runner = ChunkRunner::new(&client, &man);
    runner.run_tree(&b.sct, &args, 0, n).unwrap();
    // 32768 divides the 32768-chunk artifact: exactly one launch.
    assert_eq!(runner.launch_count(), 1);
}

#[test]
fn filter_pipeline_fused_equals_staged_through_pjrt() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    // w = 512: the staged single-filter artifacts are lowered at this width.
    let (h, w) = (64usize, 512usize);
    let img = image(9, h, w);
    let args = RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", img, w as u64)],
        scalars: vec![17.0, 0.0, 100.0],
    };
    let runner = ChunkRunner::new(&client, &man);
    let fused = workloads::filter_pipeline(h as u64, w as u64, true);
    let staged = workloads::filter_pipeline(h as u64, w as u64, false);
    let a = runner.run_tree(&fused.sct, &args, 0, h as u64).unwrap();
    let b = runner.run_tree(&staged.sct, &args, 0, h as u64).unwrap();
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
}

#[test]
fn filter_chunking_is_offset_invariant() {
    // Running rows [0,64) as one call must equal running [0,8) + [8,64)
    // separately — the dynamic row_off input at work.
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let (h, w) = (64usize, 256usize);
    let img = image(13, h, w);
    let args = RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", img, w as u64)],
        scalars: vec![5.0, 0.0, 140.0],
    };
    let runner = ChunkRunner::new(&client, &man);
    let fused = workloads::filter_pipeline(h as u64, w as u64, true);
    let whole = runner.run_tree(&fused.sct, &args, 0, h as u64).unwrap();
    let head = runner.run_tree(&fused.sct, &args, 0, 8).unwrap();
    let tail = runner.run_tree(&fused.sct, &args, 8, (h - 8) as u64).unwrap();
    let whole = whole[0].as_f32().unwrap();
    let head = head[0].as_f32().unwrap();
    let tail = tail[0].as_f32().unwrap();
    assert_eq!(&whole[..head.len()], head);
    assert_eq!(&whole[head.len()..], tail);
}

#[test]
fn fft_roundtrip_identity_through_scheduler() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let n_ffts = 32u64;
    let re = randn_vec(5, (n_ffts * 512) as usize);
    let im = randn_vec(6, (n_ffts * 512) as usize);
    let mut b = workloads::fft(1);
    b.total_units = n_ffts;
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("re", re.clone(), 512),
            VectorArg::partitioned_f32("im", im.clone(), 512),
        ],
        scalars: vec![],
    };
    let mut s = RealScheduler::new(i7_hd7950(1), &client, &man);
    let out = s.run_request(&b.sct, &args, n_ffts, &cfg(0.25)).unwrap();
    let rr = out.outputs[0].as_f32().unwrap();
    let ri = out.outputs[1].as_f32().unwrap();
    for i in 0..rr.len() {
        assert!((rr[i] - re[i]).abs() < 1e-3, "re[{i}]");
        assert!((ri[i] - im[i]).abs() < 1e-3, "im[{i}]");
    }
}

#[test]
fn nbody_chunks_match_host_direct_sum() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let n = 512usize;
    let pos = bodies(8, n);
    let b = workloads::nbody(n as u64, 1);
    let args = RequestArgs {
        vectors: vec![VectorArg::copied_f32("pos", pos.clone())],
        scalars: vec![0.0],
    };
    let mut s = RealScheduler::new(i7_hd7950(1), &client, &man);
    let out = s.run_request(&b.sct, &args, n as u64, &cfg(0.25)).unwrap();
    let acc = out.outputs[0].as_f32().unwrap();
    assert_eq!(acc.len(), n * 3);
    // Host oracle: softened direct sum (eps = 1e-3, matching the kernel).
    let eps2 = 1e-3f32 * 1e-3;
    for i in (0..n).step_by(53) {
        let mut want = [0.0f32; 3];
        for j in 0..n {
            let dx = pos[j * 4] - pos[i * 4];
            let dy = pos[j * 4 + 1] - pos[i * 4 + 1];
            let dz = pos[j * 4 + 2] - pos[i * 4 + 2];
            let r2 = dx * dx + dy * dy + dz * dz + eps2;
            let w = pos[j * 4 + 3] / (r2 * r2.sqrt());
            want[0] += dx * w;
            want[1] += dy * w;
            want[2] += dz * w;
        }
        for d in 0..3 {
            let got = acc[i * 3 + d];
            assert!(
                (got - want[d]).abs() < 2e-2 * want[d].abs().max(1.0),
                "body {i} dim {d}: {got} vs {}",
                want[d]
            );
        }
    }
}

#[test]
fn nbody_loop_host_update_advances_positions() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let n = 512usize;
    let pos0 = bodies(10, n);
    let mut b = workloads::nbody(n as u64, 2);
    let moved = std::sync::Arc::new(std::sync::atomic::AtomicU32::new(0));
    let moved2 = moved.clone();
    if let Sct::Loop { state, .. } = &mut b.sct {
        state.update = Some(Arc::new(move |_it, vecs: &mut Vec<ArgValue>, outs| {
            moved2.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if let (ArgValue::F32(p), Ok(a)) = (&mut vecs[0], outs[0].as_f32()) {
                for i in 0..p.len() / 4 {
                    for d in 0..3 {
                        p[i * 4 + d] += 1e-2 * a[i * 3 + d];
                    }
                }
            }
            true
        }));
    }
    let args = RequestArgs {
        vectors: vec![VectorArg::copied_f32("pos", pos0)],
        scalars: vec![0.0],
    };
    let mut s = RealScheduler::new(i7_hd7950(1), &client, &man);
    let out = s.run_request(&b.sct, &args, n as u64, &cfg(0.0)).unwrap();
    assert_eq!(moved.load(std::sync::atomic::Ordering::SeqCst), 2);
    assert!(out.outputs[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn segmentation_alphabet_and_layout() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let planes = 16usize;
    let vol = volume(14, planes, 32, 32);
    let mut b = workloads::segmentation(1);
    b.total_units = planes as u64;
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("vol", vol.clone(), 32 * 32),
            VectorArg::copied_f32("thresholds", vec![85.0, 170.0]),
        ],
        scalars: vec![],
    };
    let mut s = RealScheduler::new(i7_hd7950(1), &client, &man);
    let out = s.run_request(&b.sct, &args, planes as u64, &cfg(0.5)).unwrap();
    let got = out.outputs[0].as_f32().unwrap();
    for (i, (&v, &g)) in vol.iter().zip(got).enumerate() {
        let want = if v < 85.0 {
            0.0
        } else if v > 170.0 {
            255.0
        } else {
            128.0
        };
        assert_eq!(g, want, "voxel {i}");
    }
}

#[test]
fn executable_cache_compiles_each_artifact_once() {
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let info = &man.family("saxpy").unwrap()[0];
    assert_eq!(client.cached(), 0);
    let _ = client.executable(info).unwrap();
    let _ = client.executable(info).unwrap();
    assert_eq!(client.cached(), 1);
}

#[test]
fn gpu_only_and_hybrid_agree_numerically() {
    // Device placement must never change results (Section 3's single-image
    // view): the same request under different distributions is identical.
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();
    let n = 16384usize;
    let x = randn_vec(20, n);
    let y = randn_vec(21, n);
    let b = workloads::saxpy(n as u64);
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", x, 1),
            VectorArg::partitioned_f32("y", y, 1),
        ],
        scalars: vec![0.5],
    };
    let mut s = RealScheduler::new(i7_hd7950(1), &client, &man);
    let a = s.run_request(&b.sct, &args, n as u64, &cfg(0.0)).unwrap();
    let b2 = s.run_request(&b.sct, &args, n as u64, &cfg(0.5)).unwrap();
    assert_eq!(
        a.outputs[0].as_f32().unwrap(),
        b2.outputs[0].as_f32().unwrap()
    );
}

#[test]
fn drain_modes_agree_bitwise_on_pipeline_and_loop() {
    // DESIGN.md §2.7: the dataflow task-graph drain must produce outputs
    // bit-identical to the per-stage barrier drain — on a staged pipeline
    // (cross-stage overlap, carried intermediates) and on a global-sync
    // Loop (host update + COPY re-broadcast between iterations).
    use marrow::scheduler::DrainMode;
    let Some(man) = manifest() else { return };
    let client = RtClient::cpu().unwrap();

    let (h, w) = (64usize, 512usize);
    let img = image(23, h, w);
    let filter_args = RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", img, w as u64)],
        scalars: vec![17.0, 0.0, 100.0],
    };
    let staged = workloads::filter_pipeline(h as u64, w as u64, false);

    let n = 512usize;
    let pos0 = bodies(24, n);
    let nb = workloads::nbody(n as u64, 2);
    let nbody_args = RequestArgs {
        vectors: vec![VectorArg::copied_f32("pos", pos0)],
        scalars: vec![0.0],
    };

    let cases: Vec<(&Sct, &RequestArgs, u64, f64)> = vec![
        (&staged.sct, &filter_args, h as u64, 0.25),
        (&nb.sct, &nbody_args, n as u64, 0.0),
    ];
    for (sct, args, units, share) in cases {
        let run = |mode: DrainMode| {
            let mut s = RealScheduler::new(i7_hd7950(1), &client, &man);
            s.drain_mode = mode;
            s.run_request(sct, args, units, &cfg(share)).unwrap()
        };
        let barrier = run(DrainMode::Barrier);
        let dataflow = run(DrainMode::Dataflow);
        assert_eq!(barrier.outputs.len(), dataflow.outputs.len());
        for (a, b) in barrier.outputs.iter().zip(&dataflow.outputs) {
            let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            assert_eq!(a.len(), b.len());
            for i in 0..a.len() {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "elem {i} diverges");
            }
        }
    }
}
