//! Manifest JSON round-trip stability (the manifest-roundtrip idiom):
//! serialize -> parse -> re-serialize must be the identity on the canonical
//! form, and parsing must preserve every field bit-for-bit. The manifest is
//! the contract between the Python AOT pipeline and the Rust runtime, so
//! its serialized form has to be deterministic.

use std::path::Path;

use marrow::runtime::artifacts::Manifest;
use marrow::util::json::Json;

const SAMPLE: &str = r#"{"format": 1, "artifacts": [
    {"name": "saxpy_n4096", "family": "saxpy", "file": "saxpy_n4096.hlo.txt",
     "chunk_units": 4096, "flops": 8192, "bytes": 49152,
     "inputs": [{"name": "alpha", "shape": [1], "dtype": "f32"},
                {"name": "x", "shape": [4096], "dtype": "f32"}],
     "outputs": [{"name": "out", "shape": [4096], "dtype": "f32"}]},
    {"name": "saxpy_n32768", "family": "saxpy", "file": "saxpy_n32768.hlo.txt",
     "chunk_units": 32768, "flops": 65536, "bytes": 393216,
     "inputs": [], "outputs": []},
    {"name": "mirror_w512", "family": "mirror", "file": "mirror_w512.hlo.txt",
     "chunk_units": 8, "flops": 0, "bytes": 32768,
     "inputs": [{"name": "img", "shape": [8, 512], "dtype": "f32"}],
     "outputs": [{"name": "out", "shape": [8, 512], "dtype": "f32"}]}
]}"#;

#[test]
fn serialize_parse_reserialize_is_stable() {
    let dir = Path::new("artifacts");
    let m1 = Manifest::parse(SAMPLE, dir).unwrap();
    let text1 = m1.to_json().to_string_pretty();
    let m2 = Manifest::parse(&text1, dir).unwrap();
    let text2 = m2.to_json().to_string_pretty();
    assert_eq!(text1, text2, "canonical form must be a fixed point");
    // And a third trip for good measure (compact form too).
    let m3 = Manifest::parse(&text2, dir).unwrap();
    assert_eq!(m3.to_json().to_string(), m2.to_json().to_string());
}

#[test]
fn roundtrip_preserves_every_field() {
    let dir = Path::new("artifacts");
    let m1 = Manifest::parse(SAMPLE, dir).unwrap();
    let m2 = Manifest::parse(&m1.to_json().to_string_pretty(), dir).unwrap();
    assert_eq!(m1.by_family.len(), m2.by_family.len());
    for (fam, arts) in &m1.by_family {
        let back = &m2.by_family[fam];
        assert_eq!(arts.len(), back.len(), "family {fam}");
        for (a, b) in arts.iter().zip(back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.family, b.family);
            assert_eq!(a.file, b.file);
            assert_eq!(a.chunk_units, b.chunk_units);
            assert_eq!(a.flops, b.flops);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.outputs, b.outputs);
        }
    }
}

#[test]
fn canonical_form_is_family_grouped_and_chunk_sorted() {
    // The canonical serialization groups by family (sorted) and orders each
    // menu by ascending chunk size, independent of input order.
    let shuffled = r#"{"format": 1, "artifacts": [
        {"name": "b_large", "family": "b", "file": "b2.hlo.txt",
         "chunk_units": 512, "flops": 1, "bytes": 1, "inputs": [], "outputs": []},
        {"name": "a_only", "family": "a", "file": "a.hlo.txt",
         "chunk_units": 64, "flops": 1, "bytes": 1, "inputs": [], "outputs": []},
        {"name": "b_small", "family": "b", "file": "b1.hlo.txt",
         "chunk_units": 16, "flops": 1, "bytes": 1, "inputs": [], "outputs": []}
    ]}"#;
    let dir = Path::new("artifacts");
    let m = Manifest::parse(shuffled, dir).unwrap();
    let v = m.to_json();
    let names: Vec<String> = v
        .get("artifacts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|a| a.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["a_only", "b_small", "b_large"]);
    // Stability still holds from the shuffled source.
    let text1 = v.to_string_pretty();
    let text2 = Manifest::parse(&text1, dir).unwrap().to_json().to_string_pretty();
    assert_eq!(text1, text2);
}

#[test]
fn parse_accepts_what_json_parser_produces() {
    // Guard against serializer/parser drift: the serialized manifest is
    // valid JSON for the crate's own parser at the raw level too.
    let dir = Path::new("artifacts");
    let m = Manifest::parse(SAMPLE, dir).unwrap();
    let text = m.to_json().to_string_pretty();
    assert!(Json::parse(&text).is_ok());
}
