//! Prefetch-pipeline integration tests (DESIGN.md §2.12), randomized
//! with the in-tree propcheck framework (ROADMAP 5b, first slice):
//! random workload shapes × prefetch depths × steal-slack settings drain
//! through the real (native CPU) scheduler, asserting
//!
//!  * outputs are bit-identical to the depth-0 drain — prefetch moves
//!    *when* uploads happen, never what the kernels compute;
//!  * no `PendingUpload` survives the drain (the launcher's
//!    `clear_pending` runs even on error paths);
//!  * the transfer-accounting conservation sum (`bytes_uploaded +
//!    uploads_avoided_bytes + uploads_overlapped_bytes`) is invariant
//!    across prefetch depths for the same request;
//!  * residency survives prefetch pressure: a second identical request
//!    still finds its inputs resident (uploads avoided > 0).
//!
//! Failures shrink to a minimal counterexample and print a
//! `propcheck::replay(seed, case, ..)` line; the replay hook below pins
//! the generator stream so that line reproduces the exact failing case.

use marrow::bench::workloads;
use marrow::data::image::image;
use marrow::data::vector::VectorArg;
use marrow::platform::device::host_cpu;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::real::RealScheduler;
use marrow::scheduler::DrainMode;
use marrow::session::{Computation, ConfigOverride, Session};
use marrow::util::propcheck;
use marrow::util::rng::Rng;

const SEED: u64 = 0x9109;
const CASES: usize = 6;

type NativeSession = Session<RealScheduler<'static>>;

/// One random case: (workload-size selector, prefetch depth selector,
/// tasks-per-slot selector). Raw u64s so the tuple Shrink impl applies;
/// the prop maps them into their domains.
type Case = (u64, u64, u64);

fn gen(rng: &mut Rng) -> Case {
    (rng.below(3), rng.below(4), rng.below(4))
}

fn session_with(depth: u32, tasks_per_slot: u32) -> NativeSession {
    let s = Session::native(host_cpu())
        .expect("native session")
        .with_prefetch_depth(depth)
        .with_tasks_per_slot(tasks_per_slot);
    s.set_drain_mode(DrainMode::Dataflow);
    s
}

/// The unfused 3-stage filter pipeline's request: one partitioned image
/// plus the [seed, row_off placeholder, thresh] scalar layout.
fn filter_args(h: usize, w: usize) -> RequestArgs {
    RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", image(3, h, w), w as u64)],
        scalars: vec![12_345.0, 0.0, 96.0],
    }
}

fn outputs_f32(
    s: &NativeSession,
    comp: &Computation,
    args: &RequestArgs,
) -> Result<Vec<Vec<f32>>, String> {
    let out = s
        .run_with(comp, args, ConfigOverride::new())
        .map_err(|e| format!("run failed: {e}"))?;
    Ok(out
        .outputs
        .iter()
        .map(|o| o.as_f32().expect("f32 output").to_vec())
        .collect())
}

fn accounted(s: &NativeSession) -> u64 {
    let st = s.stats();
    st.bytes_uploaded + st.uploads_avoided_bytes + st.uploads_overlapped_bytes
}

fn prop(case: &Case) -> Result<(), String> {
    let &(h_sel, depth_sel, tps_sel) = case;
    let h = 32 + 32 * (h_sel % 3);
    let w = 64u64;
    let depth = (1 + depth_sel % 4) as u32; // 1..=4; depth 0 is the baseline
    let tps = (1 + tps_sel % 4) as u32;
    let comp = Computation::from(workloads::filter_pipeline(h, w, false));
    let args = filter_args(h as usize, w as usize);

    let baseline = session_with(0, tps);
    let expect = outputs_f32(&baseline, &comp, &args)?;
    let prefetching = session_with(depth, tps);
    let got = outputs_f32(&prefetching, &comp, &args)?;

    if expect.len() != got.len() {
        return Err(format!(
            "output arity differs: {} vs {}",
            expect.len(),
            got.len()
        ));
    }
    for (i, (e, g)) in expect.iter().zip(&got).enumerate() {
        if e.len() != g.len() {
            return Err(format!("output {i} length differs (h={h} depth={depth})"));
        }
        if let Some(j) = e
            .iter()
            .zip(g.iter())
            .position(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(format!(
                "depth {depth} diverges from depth 0 at output {i} elem {j}: \
                 {} vs {} (h={h} tps={tps})",
                e[j], g[j]
            ));
        }
    }

    let pending = prefetching.env().residency.pending_count();
    if pending != 0 {
        return Err(format!(
            "{pending} PendingUpload entries leaked past the drain \
             (h={h} depth={depth} tps={tps})"
        ));
    }

    let (acc0, acck) = (accounted(&baseline), accounted(&prefetching));
    if acc0 != acck {
        return Err(format!(
            "conservation sum depends on prefetch depth: {acc0} at depth 0 \
             vs {acck} at depth {depth} (h={h} tps={tps})"
        ));
    }

    // Residency survives prefetch pressure: the second identical request
    // must still find its inputs resident.
    outputs_f32(&prefetching, &comp, &args)?;
    let st = prefetching.stats();
    if st.uploads_avoided == 0 {
        return Err(format!(
            "second request found nothing resident after a depth-{depth} \
             drain: {st:?}"
        ));
    }
    if prefetching.env().residency.pending_count() != 0 {
        return Err("second drain leaked pending uploads".into());
    }
    Ok(())
}

#[test]
fn prefetch_drain_matches_depth_zero_bitwise_under_random_shapes() {
    propcheck::forall(SEED, CASES, gen, prop);
}

/// The deterministic replay hook the forall failure message points at:
/// `propcheck::replay(SEED, case, gen, prop)` regenerates the exact value
/// case `case` drew (the generator stream is a pure function of the
/// seed). Pinning case 0 here keeps the stream stable — if the generator
/// changes shape, this fails before a real failure's replay line lies.
#[test]
fn failing_seed_replay_is_deterministic() {
    assert_eq!(propcheck::replay(SEED, 0, gen, prop), Ok(()));
    let mut rng = Rng::new(SEED);
    let first = gen(&mut rng);
    let mut rng2 = Rng::new(SEED);
    assert_eq!(first, gen(&mut rng2), "generator must be seed-deterministic");
}
