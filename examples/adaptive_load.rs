//! Adaptive load-balancing demo (the Fig 11 scenario, simulated clock)
//! through the `Session` facade: an FFT workload runs steadily until an
//! external application floods the CPU with compute threads; the session's
//! monitor detects the unbalance and the adaptive binary search shifts work
//! to the GPU — all inside `Session::run`, no manual balancer wiring.
//!
//! Run with: `cargo run --release --example adaptive_load`.

use marrow::bench::workloads;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::session::{Computation, Session};
use marrow::sim::cpuload::LoadProfile;
use marrow::sim::machine::SimMachine;

fn main() -> marrow::Result<()> {
    let comp = Computation::from(workloads::fft(128));
    let args = RequestArgs::default();

    // Profile under stable load; the tuned profile lands in the KB.
    let tuned = Session::simulated(i7_hd7950(1), 99);
    let profile = tuned.profile(&comp)?;
    println!(
        "profiled distribution: GPU {:.1}% / CPU {:.1}% (fission {}, overlap {:?})",
        100.0 * profile.config.gpu_share(),
        100.0 * profile.config.cpu_share,
        profile.config.fission.label(),
        profile.config.overlap
    );

    // Re-run on a machine with a load spike at run 15 (9 external compute
    // threads), inheriting the warm KB: every run is a KB hit and the
    // session's balancer refines the stored distribution in place.
    let sim = SimMachine::new(i7_hd7950(1), 100).with_load(LoadProfile::step_at(15, 9));
    let s = Session::sim(sim).with_kb(tuned.into_kb());

    println!("\n run | GPU share | exec time | event");
    println!("-----+-----------+-----------+-------");
    for run in 0..60u64 {
        let out = s.run(&comp, &args)?;
        let event = if run == 15 {
            "<- load spike (9 threads)"
        } else if out.rebalanced {
            "<- balance op"
        } else {
            ""
        };
        if run % 3 == 0 || !event.is_empty() {
            println!(
                " {run:>3} |   {:>5.1}%  | {:>7.2}ms | {event}",
                100.0 * out.config.gpu_share(),
                out.exec.total * 1e3
            );
        }
    }
    let st = s.stats();
    println!(
        "\n{} balance operations, {} unbalanced runs out of {}",
        st.balance_ops, st.unbalanced_runs, st.runs
    );
    println!("adaptive_load OK");
    Ok(())
}
