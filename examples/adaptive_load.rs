//! Adaptive load-balancing demo (the Fig 11 scenario, simulated clock):
//! an FFT workload runs steadily until an external application floods the
//! CPU with compute threads; the monitor detects the unbalance and the
//! adaptive binary search shifts work to the GPU.
//!
//! Run with: `cargo run --release --example adaptive_load`.

use marrow::balance::LoadBalancer;
use marrow::bench::workloads;
use marrow::platform::device::i7_hd7950;
use marrow::scheduler::SimEnv;
use marrow::sim::cpuload::LoadProfile;
use marrow::sim::machine::SimMachine;
use marrow::tuner::builder::{build_profile, TunerOpts};

fn main() -> marrow::Result<()> {
    let b = workloads::fft(128);

    // Profile under stable load.
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 99));
    env.copy_bytes = b.copy_bytes;
    let profile = build_profile(
        &mut env,
        &b.sct,
        &b.workload,
        b.total_units,
        &TunerOpts::default(),
    )?;
    let mut cfg = profile.config.clone();
    println!(
        "profiled distribution: GPU {:.1}% / CPU {:.1}% (fission {}, overlap {:?})",
        100.0 * cfg.gpu_share(),
        100.0 * cfg.cpu_share,
        cfg.fission.label(),
        cfg.overlap
    );

    // Re-run with a load spike at run 15: 9 external compute threads.
    let sim = SimMachine::new(i7_hd7950(1), 100).with_load(LoadProfile::step_at(15, 9));
    let mut env = SimEnv::new(sim);
    env.copy_bytes = b.copy_bytes;
    let mut lb = LoadBalancer::new(0.85, cfg.cpu_share);

    println!("\n run | GPU share | exec time | event");
    println!("-----+-----------+-----------+-------");
    for run in 0..60u64 {
        let ops = lb.balance_ops;
        let out = lb.step(&mut env, &b.sct, b.total_units, &mut cfg)?;
        let event = if run == 15 {
            "<- load spike (9 threads)"
        } else if lb.balance_ops > ops {
            "<- balance op"
        } else {
            ""
        };
        if run % 3 == 0 || !event.is_empty() {
            println!(
                " {run:>3} |   {:>5.1}%  | {:>7.2}ms | {event}",
                100.0 * cfg.gpu_share(),
                out.total * 1e3
            );
        }
    }
    println!(
        "\n{} balance operations, {} unbalanced runs out of 60",
        lb.balance_ops, lb.unbalanced_runs
    );
    println!("adaptive_load OK");
    Ok(())
}
