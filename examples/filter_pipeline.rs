//! Filter Pipeline example: the paper's compound 3-kernel computation
//! (Gaussian Noise -> Solarize -> Mirror) on a real image, executed both as
//! the locality-aware fused SCT (one HLO) and as the staged 3-kernel
//! Pipeline — and checked bit-identical, which exercises Section 3.1's
//! claim that consecutive kernels can persist data under identical
//! partitionings.
//!
//! Run with: `cargo run --release --example filter_pipeline`.

use marrow::bench::workloads;
use marrow::data::image::image;
use marrow::data::vector::VectorArg;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::real::RealScheduler;
use marrow::tuner::profile::FrameworkConfig;

fn main() -> marrow::Result<()> {
    let (h, w) = (192usize, 512usize);
    let img = image(7, h, w);
    let seed = 42.0;
    let thresh = 128.0;

    let manifest = Manifest::load_default()?;
    let client = RtClient::cpu()?;
    let cfg = FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: 0.25,
    };
    // Request scalars: [seed, row_off placeholder (Offset trait), thresh].
    let args = RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", img.clone(), w as u64)],
        scalars: vec![seed, 0.0, thresh],
    };

    // Locality-aware fused SCT.
    let fused = workloads::filter_pipeline(h as u64, w as u64, true);
    let mut sched = RealScheduler::new(i7_hd7950(1), &client, &manifest);
    let out_fused = sched.run_request(&fused.sct, &args, h as u64, &cfg)?;
    let fused_launches = sched.launches;

    // Staged 3-kernel Pipeline (the ablation path).
    let staged = workloads::filter_pipeline(h as u64, w as u64, false);
    let mut sched2 = RealScheduler::new(i7_hd7950(1), &client, &manifest);
    let out_staged = sched2.run_request(&staged.sct, &args, h as u64, &cfg)?;

    let a = out_fused.outputs[0].as_f32()?;
    let b = out_staged.outputs[0].as_f32()?;
    assert_eq!(a.len(), h * w);
    let max_err = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!(
        "filter pipeline {h}x{w}: fused {:.3} ms ({} launches) vs staged {:.3} ms ({} launches)",
        out_fused.exec.total * 1e3,
        fused_launches,
        out_staged.exec.total * 1e3,
        sched2.launches,
    );
    println!("fused vs staged max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3, "fused and staged pipelines must agree");

    // Sanity: mirror actually flipped — compare first row against the
    // un-mirrored intermediate ordering (monotony of the gradient breaks).
    assert!(a.iter().any(|&v| v != img[0]), "output must differ from input");
    println!("filter_pipeline OK");
    Ok(())
}
