//! Filter Pipeline example: the paper's compound 3-kernel computation
//! (Gaussian Noise -> Solarize -> Mirror) through the `Session` facade,
//! executed both as the locality-aware fused SCT (one HLO) and as the
//! staged 3-kernel Pipeline — and checked bit-identical, which exercises
//! Section 3.1's claim that consecutive kernels can persist data under
//! identical partitionings.
//!
//! Both variants run under the same pinned hybrid split
//! (`ConfigOverride::cpu_share(0.25)`), so the timing difference isolates
//! the locality effect. Without artifacts/PJRT the example reports the
//! simulated comparison instead.
//!
//! Run with: `cargo run --release --example filter_pipeline`.

use marrow::bench::workloads;
use marrow::data::image::image;
use marrow::data::vector::VectorArg;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::session::{Computation, ConfigOverride, Session};

fn main() -> marrow::Result<()> {
    let (h, w) = (192usize, 512usize);
    let img = image(7, h, w);
    let seed = 42.0;
    let thresh = 128.0;

    // Request scalars: [seed, row_off placeholder (Offset trait), thresh].
    let args = RequestArgs {
        vectors: vec![VectorArg::partitioned_f32("img", img.clone(), w as u64)],
        scalars: vec![seed, 0.0, thresh],
    };
    let fused = Computation::from(workloads::filter_pipeline(h as u64, w as u64, true));
    let staged = Computation::from(workloads::filter_pipeline(h as u64, w as u64, false));
    let hybrid = ConfigOverride::new().cpu_share(0.25);

    match (Manifest::load_default(), RtClient::cpu()) {
        (Ok(manifest), Ok(client)) => {
            // Locality-aware fused SCT vs the staged ablation path, each in
            // its own session (separate launch counters).
            let sf = Session::real(i7_hd7950(1), &client, &manifest);
            let out_fused = sf.run_with(&fused, &args, hybrid.clone())?;
            let ss = Session::real(i7_hd7950(1), &client, &manifest);
            let out_staged = ss.run_with(&staged, &args, hybrid)?;

            let a = out_fused.outputs[0].as_f32()?;
            let b = out_staged.outputs[0].as_f32()?;
            assert_eq!(a.len(), h * w);
            let max_err = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            println!(
                "filter pipeline {h}x{w}: fused {:.3} ms ({} launches) vs staged \
                 {:.3} ms ({} launches)",
                out_fused.exec.total * 1e3,
                out_fused.launches,
                out_staged.exec.total * 1e3,
                out_staged.launches,
            );
            println!("fused vs staged max |err| = {max_err:.2e}");
            assert!(max_err < 1e-3, "fused and staged pipelines must agree");

            // Sanity: the filters actually transformed the image.
            assert!(
                a.iter().any(|&v| v != img[0]),
                "output must differ from input"
            );
        }
        (man, client) => {
            if let Some(e) = man.err().or(client.err()) {
                println!("real runtime unavailable ({e}); running simulated");
            }
            let s = Session::simulated(i7_hd7950(1), 7);
            let out_fused = s.run_with(&fused, &args, hybrid.clone())?;
            let out_staged = s.run_with(&staged, &args, hybrid)?;
            println!(
                "filter pipeline {h}x{w} (simulated clock): fused {:.3} ms vs \
                 staged {:.3} ms",
                out_fused.exec.total * 1e3,
                out_staged.exec.total * 1e3,
            );
        }
    }
    println!("filter_pipeline OK");
    Ok(())
}
