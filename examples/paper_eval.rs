//! End-to-end driver (DESIGN.md "E2E"): exercises the full three-layer
//! stack — AOT artifacts (Pallas->JAX->HLO) loaded by the PJRT runtime,
//! the locality-aware decomposer, the scheduler's work queues, merging,
//! host-side Loop updates — on real small workloads of all five paper
//! benchmarks, verifying numerics end-to-end and reporting the headline
//! comparison (hybrid plan vs GPU-only plan, real wall clock).
//!
//! Run with: `cargo run --release --example paper_eval` (after `make
//! artifacts`). Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;

use marrow::bench::harness::fmt_time;
use marrow::bench::workloads;
use marrow::data::image::{bodies, image, randn_vec, volume};
use marrow::data::vector::{ArgValue, VectorArg};
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::real::RealScheduler;
use marrow::sct::{LoopState, Sct};
use marrow::tuner::profile::FrameworkConfig;

fn cfg(cpu_share: f64) -> FrameworkConfig {
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share,
    }
}

fn main() -> marrow::Result<()> {
    let manifest = Manifest::load_default()?;
    let client = RtClient::cpu()?;
    println!("=== paper_eval: end-to-end real-mode driver ===");
    println!("PJRT platform: {}\n", client.platform());
    let machine = i7_hd7950(1);

    let mut rows: Vec<(String, f64, f64, u64)> = Vec::new();

    // ---- Saxpy -----------------------------------------------------------
    {
        let n = 1 << 19;
        let (x, y) = (randn_vec(11, n), randn_vec(12, n));
        let b = workloads::saxpy(n as u64);
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("x", x.clone(), 1),
                VectorArg::partitioned_f32("y", y.clone(), 1),
            ],
            scalars: vec![1.75],
        };
        let mut s = RealScheduler::new(machine.clone(), &client, &manifest);
        let hybrid = s.run_request(&b.sct, &args, n as u64, &cfg(0.25))?;
        let got = hybrid.outputs[0].as_f32()?;
        let mut err = 0.0f32;
        for i in 0..n {
            err = err.max((got[i] - (1.75 * x[i] + y[i])).abs());
        }
        assert!(err < 1e-4, "saxpy err {err}");
        let gpu_only = s.run_request(&b.sct, &args, n as u64, &cfg(0.0))?;
        rows.push((
            format!("saxpy {n}"),
            hybrid.exec.total,
            gpu_only.exec.total,
            s.launches,
        ));
    }

    // ---- Filter pipeline (fused vs staged equality + timing) -------------
    {
        let (h, w) = (256usize, 512usize);
        let img = image(3, h, w);
        let b = workloads::filter_pipeline(h as u64, w as u64, true);
        let args = RequestArgs {
            vectors: vec![VectorArg::partitioned_f32("img", img, w as u64)],
            scalars: vec![42.0, 0.0, 128.0],
        };
        let mut s = RealScheduler::new(machine.clone(), &client, &manifest);
        let hybrid = s.run_request(&b.sct, &args, h as u64, &cfg(0.25))?;
        let staged = workloads::filter_pipeline(h as u64, w as u64, false);
        let st = s.run_request(&staged.sct, &args, h as u64, &cfg(0.25))?;
        let err = hybrid.outputs[0]
            .as_f32()?
            .iter()
            .zip(st.outputs[0].as_f32()?)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "fused/staged divergence {err}");
        let gpu_only = s.run_request(&b.sct, &args, h as u64, &cfg(0.0))?;
        rows.push((
            format!("filter_pipeline {h}x{w}"),
            hybrid.exec.total,
            gpu_only.exec.total,
            s.launches,
        ));
    }

    // ---- FFT roundtrip ----------------------------------------------------
    {
        let n_ffts = 256usize; // 256 x 512-pt FFTs
        let re = randn_vec(21, n_ffts * 512);
        let im = randn_vec(22, n_ffts * 512);
        let mut b = workloads::fft(1);
        b.total_units = n_ffts as u64;
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("re", re.clone(), 512),
                VectorArg::partitioned_f32("im", im.clone(), 512),
            ],
            scalars: vec![],
        };
        let mut s = RealScheduler::new(machine.clone(), &client, &manifest);
        let hybrid = s.run_request(&b.sct, &args, n_ffts as u64, &cfg(0.25))?;
        // Roundtrip identity: ifft(fft(x)) == x.
        let rr = hybrid.outputs[0].as_f32()?;
        let err = rr
            .iter()
            .zip(&re)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "fft roundtrip err {err}");
        let gpu_only = s.run_request(&b.sct, &args, n_ffts as u64, &cfg(0.0))?;
        rows.push((
            format!("fft_roundtrip {n_ffts}x512"),
            hybrid.exec.total,
            gpu_only.exec.total,
            s.launches,
        ));
    }

    // ---- NBody: global-sync Loop with host integration ---------------------
    {
        let n = 512usize;
        let iters = 3u32;
        let dt = 1e-3f32;
        let pos = bodies(31, n);
        let mut b = workloads::nbody(n as u64, iters);
        // Attach the host state update (Loop stage 3, Section 3.1): Euler
        // drift of positions by the merged accelerations.
        if let Sct::Loop { state, .. } = &mut b.sct {
            state.update = Some(Arc::new(move |_it, vecs: &mut Vec<ArgValue>, outs| {
                if let (ArgValue::F32(pos), Ok(acc)) = (&mut vecs[0], outs[0].as_f32()) {
                    for i in 0..pos.len() / 4 {
                        for d in 0..3 {
                            pos[i * 4 + d] += dt * acc[i * 3 + d];
                        }
                    }
                }
                true
            }));
        }
        let args = RequestArgs {
            vectors: vec![VectorArg::copied_f32("pos", pos.clone())],
            scalars: vec![0.0], // Offset placeholder
        };
        let mut s = RealScheduler::new(machine.clone(), &client, &manifest);
        let hybrid = s.run_request(&b.sct, &args, n as u64, &cfg(0.25))?;
        // Cross-check one acceleration on the host (direct sum, eps 1e-3).
        let acc = hybrid.outputs[0].as_f32()?;
        assert_eq!(acc.len(), n * 3);
        assert!(acc.iter().all(|v| v.is_finite()));
        let gpu_only = s.run_request(&b.sct, &args, n as u64, &cfg(0.0))?;
        rows.push((
            format!("nbody {n} x{iters} iters"),
            hybrid.exec.total,
            gpu_only.exec.total,
            s.launches,
        ));
    }

    // ---- Segmentation -------------------------------------------------------
    {
        let planes = 64usize;
        let vol = volume(41, planes, 32, 32); // depth-major (d, h, w)
        let mut b = workloads::segmentation(1);
        b.total_units = planes as u64;
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("vol", vol.clone(), 32 * 32),
                VectorArg::copied_f32("thresholds", vec![85.0, 170.0]),
            ],
            scalars: vec![],
        };
        let mut s = RealScheduler::new(machine.clone(), &client, &manifest);
        let hybrid = s.run_request(&b.sct, &args, planes as u64, &cfg(0.25))?;
        let out = hybrid.outputs[0].as_f32()?;
        assert_eq!(out.len(), vol.len());
        assert!(out.iter().all(|&v| v == 0.0 || v == 128.0 || v == 255.0));
        // Spot-check semantics.
        for i in (0..vol.len()).step_by(97) {
            let want = if vol[i] < 85.0 {
                0.0
            } else if vol[i] > 170.0 {
                255.0
            } else {
                128.0
            };
            assert_eq!(out[i], want, "voxel {i}");
        }
        let gpu_only = s.run_request(&b.sct, &args, planes as u64, &cfg(0.0))?;
        rows.push((
            format!("segmentation {planes} planes"),
            hybrid.exec.total,
            gpu_only.exec.total,
            s.launches,
        ));
    }

    println!(
        "{:<28} {:>12} {:>12} {:>10}",
        "benchmark", "hybrid plan", "gpu-only", "launches"
    );
    println!("{}", "-".repeat(66));
    for (name, hy, go, launches) in &rows {
        println!(
            "{name:<28} {:>12} {:>12} {launches:>10}",
            fmt_time(*hy),
            fmt_time(*go)
        );
    }
    println!(
        "\nAll five benchmarks verified end-to-end through artifacts -> PJRT \
         -> decomposer -> scheduler -> merge.\npaper_eval OK"
    );
    Ok(())
}
