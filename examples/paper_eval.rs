//! End-to-end driver (DESIGN.md "E2E"): exercises the full three-layer
//! stack — AOT artifacts (Pallas->JAX->HLO) loaded by the PJRT runtime,
//! the locality-aware decomposer, the scheduler's work queues, merging,
//! host-side Loop updates — on real small workloads of all five paper
//! benchmarks, verifying numerics end-to-end and reporting the headline
//! comparison (hybrid plan vs GPU-only plan, real wall clock).
//!
//! Every request goes through the `Session` facade; the hybrid/GPU-only
//! A/B uses pinned `ConfigOverride`s so the comparison is deterministic.
//!
//! Run with: `cargo run --release --example paper_eval` (after `make
//! artifacts`). Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;

use marrow::bench::harness::fmt_time;
use marrow::bench::workloads;
use marrow::data::image::{bodies, image, randn_vec, volume};
use marrow::data::vector::{ArgValue, VectorArg};
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::sct::Sct;
use marrow::session::{Computation, ConfigOverride, Session};

fn hybrid() -> ConfigOverride {
    ConfigOverride::new().cpu_share(0.25)
}

fn main() -> marrow::Result<()> {
    let manifest = Manifest::load_default()?;
    let client = RtClient::cpu()?;
    println!("=== paper_eval: end-to-end real-mode driver ===");
    println!("PJRT platform: {}\n", client.platform());
    let machine = i7_hd7950(1);

    let mut rows: Vec<(String, f64, f64, u64)> = Vec::new();

    // ---- Saxpy -----------------------------------------------------------
    {
        let n = 1 << 19;
        let (x, y) = (randn_vec(11, n), randn_vec(12, n));
        let comp = Computation::from(workloads::saxpy(n as u64));
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("x", x.clone(), 1),
                VectorArg::partitioned_f32("y", y.clone(), 1),
            ],
            scalars: vec![1.75],
        };
        let s = Session::real(machine.clone(), &client, &manifest);
        let hy = s.run_with(&comp, &args, hybrid())?;
        let got = hy.outputs[0].as_f32()?;
        let mut err = 0.0f32;
        for i in 0..n {
            err = err.max((got[i] - (1.75 * x[i] + y[i])).abs());
        }
        assert!(err < 1e-4, "saxpy err {err}");
        let go = s.run_with(&comp, &args, ConfigOverride::new().gpu_only())?;
        rows.push((format!("saxpy {n}"), hy.exec.total, go.exec.total, go.launches));
    }

    // ---- Filter pipeline (fused vs staged equality + timing) -------------
    {
        let (h, w) = (256usize, 512usize);
        let img = image(3, h, w);
        let fused = Computation::from(workloads::filter_pipeline(h as u64, w as u64, true));
        let staged =
            Computation::from(workloads::filter_pipeline(h as u64, w as u64, false));
        let args = RequestArgs {
            vectors: vec![VectorArg::partitioned_f32("img", img, w as u64)],
            scalars: vec![42.0, 0.0, 128.0],
        };
        let s = Session::real(machine.clone(), &client, &manifest);
        let hy = s.run_with(&fused, &args, hybrid())?;
        let st = s.run_with(&staged, &args, hybrid())?;
        let err = hy.outputs[0]
            .as_f32()?
            .iter()
            .zip(st.outputs[0].as_f32()?)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "fused/staged divergence {err}");
        let go = s.run_with(&fused, &args, ConfigOverride::new().gpu_only())?;
        rows.push((
            format!("filter_pipeline {h}x{w}"),
            hy.exec.total,
            go.exec.total,
            go.launches,
        ));
    }

    // ---- FFT roundtrip ----------------------------------------------------
    {
        let n_ffts = 256usize; // 256 x 512-pt FFTs
        let re = randn_vec(21, n_ffts * 512);
        let im = randn_vec(22, n_ffts * 512);
        let comp = Computation::from(workloads::fft(1)).units(n_ffts as u64);
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("re", re.clone(), 512),
                VectorArg::partitioned_f32("im", im.clone(), 512),
            ],
            scalars: vec![],
        };
        let s = Session::real(machine.clone(), &client, &manifest);
        let hy = s.run_with(&comp, &args, hybrid())?;
        // Roundtrip identity: ifft(fft(x)) == x.
        let rr = hy.outputs[0].as_f32()?;
        let err = rr
            .iter()
            .zip(&re)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "fft roundtrip err {err}");
        let go = s.run_with(&comp, &args, ConfigOverride::new().gpu_only())?;
        rows.push((
            format!("fft_roundtrip {n_ffts}x512"),
            hy.exec.total,
            go.exec.total,
            go.launches,
        ));
    }

    // ---- NBody: global-sync Loop with host integration ---------------------
    {
        let n = 512usize;
        let iters = 3u32;
        let dt = 1e-3f32;
        let pos = bodies(31, n);
        let mut comp = Computation::from(workloads::nbody(n as u64, iters));
        // Attach the host state update (Loop stage 3, Section 3.1): Euler
        // drift of positions by the merged accelerations.
        if let Sct::Loop { state, .. } = comp.sct_mut() {
            state.update = Some(Arc::new(move |_it, vecs: &mut Vec<ArgValue>, outs| {
                if let (ArgValue::F32(pos), Ok(acc)) = (&mut vecs[0], outs[0].as_f32()) {
                    for i in 0..pos.len() / 4 {
                        for d in 0..3 {
                            pos[i * 4 + d] += dt * acc[i * 3 + d];
                        }
                    }
                }
                true
            }));
        }
        let args = RequestArgs {
            vectors: vec![VectorArg::copied_f32("pos", pos.clone())],
            scalars: vec![0.0], // Offset placeholder
        };
        let s = Session::real(machine.clone(), &client, &manifest);
        let hy = s.run_with(&comp, &args, hybrid())?;
        let acc = hy.outputs[0].as_f32()?;
        assert_eq!(acc.len(), n * 3);
        assert!(acc.iter().all(|v| v.is_finite()));
        let go = s.run_with(&comp, &args, ConfigOverride::new().gpu_only())?;
        rows.push((
            format!("nbody {n} x{iters} iters"),
            hy.exec.total,
            go.exec.total,
            go.launches,
        ));
    }

    // ---- Segmentation -------------------------------------------------------
    {
        let planes = 64usize;
        let vol = volume(41, planes, 32, 32); // depth-major (d, h, w)
        let comp = Computation::from(workloads::segmentation(1)).units(planes as u64);
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("vol", vol.clone(), 32 * 32),
                VectorArg::copied_f32("thresholds", vec![85.0, 170.0]),
            ],
            scalars: vec![],
        };
        let s = Session::real(machine.clone(), &client, &manifest);
        let hy = s.run_with(&comp, &args, hybrid())?;
        let out = hy.outputs[0].as_f32()?;
        assert_eq!(out.len(), vol.len());
        assert!(out.iter().all(|&v| v == 0.0 || v == 128.0 || v == 255.0));
        // Spot-check semantics.
        for i in (0..vol.len()).step_by(97) {
            let want = if vol[i] < 85.0 {
                0.0
            } else if vol[i] > 170.0 {
                255.0
            } else {
                128.0
            };
            assert_eq!(out[i], want, "voxel {i}");
        }
        let go = s.run_with(&comp, &args, ConfigOverride::new().gpu_only())?;
        rows.push((
            format!("segmentation {planes} planes"),
            hy.exec.total,
            go.exec.total,
            go.launches,
        ));
    }

    println!(
        "{:<28} {:>12} {:>12} {:>10}",
        "benchmark", "hybrid plan", "gpu-only", "launches"
    );
    println!("{}", "-".repeat(66));
    for (name, hy, go, launches) in &rows {
        println!(
            "{name:<28} {:>12} {:>12} {launches:>10}",
            fmt_time(*hy),
            fmt_time(*go)
        );
    }
    println!(
        "\nAll five benchmarks verified end-to-end through artifacts -> PJRT \
         -> decomposer -> scheduler -> merge, driven by the Session facade.\npaper_eval OK"
    );
    Ok(())
}
