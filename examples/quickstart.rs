//! Quickstart: the `Session` facade end-to-end.
//!
//! One `Computation` (a Saxpy map), one `Session` per backend — the session
//! owns the scheduler, the knowledge base and the balancer, so there is no
//! manual `Manifest`/`RealScheduler`/`FrameworkConfig` wiring here:
//!
//!  1. a *simulated* session runs Algorithm 1 and stores the tuned profile
//!     in its knowledge base (fast: analytic cost model);
//!  2. a *real* (PJRT) session inherits that KB, so its first `run` is
//!     already a knowledge-base hit — the paper's "seamless" path — and the
//!     numerics are verified against the host.
//!
//! Without `make artifacts` (or without the `pjrt` feature) step 2 falls
//! back to the simulator and only reports timings.
//!
//! Run with: `cargo run --release --example quickstart`.

use marrow::bench::workloads;
use marrow::data::image::randn_vec;
use marrow::data::vector::VectorArg;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::session::{Computation, Session};

fn main() -> marrow::Result<()> {
    let n: usize = 1 << 18; // 262,144 elements
    let alpha = 2.5f32;

    // 1. Host data + the computation (a Map skeleton over the saxpy kernel).
    let x = randn_vec(1, n);
    let y = randn_vec(2, n);
    let comp = Computation::from(workloads::saxpy(n as u64));
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", x.clone(), 1),
            VectorArg::partitioned_f32("y", y.clone(), 1),
        ],
        scalars: vec![alpha as f64],
    };

    // 2. Tune in the simulator; the profile lands in the session's KB.
    let sim = Session::simulated(i7_hd7950(1), 42);
    let profile = sim.profile(&comp)?;
    println!(
        "simulated profile: GPU {:.1}% / CPU {:.1}% (fission {}, overlap {:?}, wgs {})",
        100.0 * profile.config.gpu_share(),
        100.0 * profile.config.cpu_share,
        profile.config.fission.label(),
        profile.config.overlap,
        profile.config.wgs,
    );

    // 3. Run for real through the same facade, seeded with the sim-built KB.
    match (Manifest::load_default(), RtClient::cpu()) {
        (Ok(manifest), Ok(client)) => {
            println!("platform: {}", client.platform());
            let s =
                Session::real(i7_hd7950(1), &client, &manifest).with_kb(sim.into_kb());
            let out = s.run(&comp, &args)?;

            // 4. Verify against the host computation.
            let got = out.outputs[0].as_f32()?;
            assert_eq!(got.len(), n);
            let mut max_err = 0.0f32;
            for i in 0..n {
                let want = alpha * x[i] + y[i];
                max_err = max_err.max((got[i] - want).abs());
            }
            println!(
                "saxpy n={n}: total {:.3} ms over {} slots ({} chunk launches, \
                 config {}), max |err| = {max_err:.2e}",
                out.exec.total * 1e3,
                out.exec.slot_times.len(),
                out.launches,
                out.origin.label(),
            );
            assert!(max_err < 1e-4, "numerics mismatch");
        }
        (man, client) => {
            if let Some(e) = man.err().or(client.err()) {
                println!("real runtime unavailable ({e}); running simulated");
            }
            let out = sim.run(&comp, &args)?;
            println!(
                "saxpy n={n} (simulated clock): total {:.3} ms over {} slots, config {}",
                out.exec.total * 1e3,
                out.exec.slot_times.len(),
                out.origin.label(),
            );
        }
    }
    println!("quickstart OK");
    Ok(())
}
