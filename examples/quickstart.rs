//! Quickstart: build a Saxpy SCT, execute it on the real PJRT runtime under
//! a hybrid CPU/GPU partition plan, and verify the numerics.
//!
//! Run with: `cargo run --release --example quickstart` (after `make artifacts`).

use marrow::bench::workloads;
use marrow::data::image::randn_vec;
use marrow::data::vector::VectorArg;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::real::RealScheduler;
use marrow::tuner::profile::FrameworkConfig;

fn main() -> marrow::Result<()> {
    let n: usize = 1 << 18; // 262,144 elements
    let alpha = 2.5f32;

    // 1. Host data.
    let x = randn_vec(1, n);
    let y = randn_vec(2, n);

    // 2. The SCT: a Map skeleton over the saxpy kernel (Section 2.1).
    let bench = workloads::saxpy(n as u64);

    // 3. Runtime: PJRT CPU client + AOT artifact manifest.
    let manifest = Manifest::load_default()?;
    let client = RtClient::cpu()?;
    println!("platform: {}", client.platform());

    // 4. A hybrid framework configuration (fission L2, overlap 2, 25% CPU —
    //    in production this comes from the tuner/KB; see `marrow profile`).
    let cfg = FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: 0.25,
    };

    // 5. Execute the request.
    let mut sched = RealScheduler::new(i7_hd7950(1), &client, &manifest);
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", x.clone(), 1),
            VectorArg::partitioned_f32("y", y.clone(), 1),
        ],
        scalars: vec![alpha as f64],
    };
    let out = sched.run_request(&bench.sct, &args, n as u64, &cfg)?;

    // 6. Verify against the host computation.
    let got = out.outputs[0].as_f32()?;
    assert_eq!(got.len(), n);
    let mut max_err = 0.0f32;
    for i in 0..n {
        let want = alpha * x[i] + y[i];
        max_err = max_err.max((got[i] - want).abs());
    }
    println!(
        "saxpy n={n}: total {:.3} ms over {} slots ({} chunk launches), max |err| = {max_err:.2e}",
        out.exec.total * 1e3,
        out.exec.slot_times.len(),
        sched.launches,
    );
    assert!(max_err < 1e-4, "numerics mismatch");
    println!("quickstart OK");
    Ok(())
}
